"""Per-architecture smoke tests (deliverable f): every assigned arch + the
paper's mixtral, as a REDUCED same-family config — one forward + one train
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, tiny_config
from repro.models import RunCtx, build_model
from repro.training.train_step import TrainConfig, make_train_step

CTX = RunCtx(mode="train", attn_backend="xla", moe_strategy="capacity",
             block_q=16, block_kv=16)


def _batch(cfg, B=2, S=24, seed=0):
    r = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32),
             "labels": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(r.standard_normal((B, 12, cfg.d_model)), jnp.float32)
    if cfg.vision is not None:
        batch["patches"] = jnp.asarray(
            r.standard_normal((B, cfg.vision.n_patches, cfg.vision.d_patch)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch, CTX)
    S_out = batch["tokens"].shape[1] + (cfg.vision.n_patches if cfg.vision else 0)
    assert logits.shape == (2, S_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    init_fn, step_fn = make_train_step(model, TrainConfig(peak_lr=1e-3, remat=True), CTX)
    state = init_fn(params)
    batch = _batch(cfg)
    new_params, state, metrics = jax.jit(step_fn)(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    delta = sum(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_count_positive(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    na = cfg.active_param_count()
    assert n > 1e9 and 0 < na <= n
