"""Mamba2 SSD: the chunked algorithm vs a naive sequential recurrence oracle,
and chunk-size invariance."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba import ssd_chunked


def naive_ssd(x, dt, A, B_, C, init_state=None):
    """Direct recurrence: s_t = exp(dt_t A) s_{t-1} + dt_t B_t (x) x_t;
    y_t = C_t . s_t."""
    Bb, L, H, P = x.shape
    N = B_.shape[-1]
    s = np.zeros((Bb, H, P, N)) if init_state is None else np.asarray(init_state)
    ys = []
    x, dt, A, B_, C = map(np.asarray, (x, dt, A, B_, C))
    for t in range(L):
        decay = np.exp(dt[:, t] * A[None, :])                    # (B,H)
        s = s * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], B_[:, t])
        ys.append(np.einsum("bhn,bhpn->bhp", C[:, t], s))
    return np.stack(ys, 1), s


@pytest.mark.parametrize("L,chunk", [(16, 4), (17, 4), (32, 8), (8, 16)])
def test_chunked_matches_naive(rng, L, chunk):
    Bb, H, P, N = 2, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (Bb, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    y, s = ssd_chunked(x, dt, A, B_, C, chunk)
    y_ref, s_ref = naive_ssd(x, dt, A, B_, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=1e-4, rtol=1e-4)


def test_chunk_size_invariance(rng):
    Bb, L, H, P, N = 1, 24, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (Bb, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    y4, _ = ssd_chunked(x, dt, A, B_, C, 4)
    y8, _ = ssd_chunked(x, dt, A, B_, C, 8)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8), atol=1e-4)


def test_init_state_continuation(rng):
    """Processing [first half] then [second half with carried state] must
    equal processing the full sequence (chunked-prefill invariant)."""
    Bb, L, H, P, N = 1, 16, 2, 3, 4
    x = jnp.asarray(rng.standard_normal((Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (Bb, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bb, L, H, N)), jnp.float32)
    y_full, s_full = ssd_chunked(x, dt, A, B_, C, 4)
    h = L // 2
    y1, s1 = ssd_chunked(x[:, :h], dt[:, :h], A, B_[:, :h], C[:, :h], 4)
    y2, s2 = ssd_chunked(x[:, h:], dt[:, h:], A, B_[:, h:], C[:, h:], 4, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)
