"""Checkpointing (atomic, async, roundtrip) + trainer crash/restart
equivalence + optimizer reference check + data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint
from repro.configs import tiny_config
from repro.data.pipeline import DataConfig, synthesize_batch
from repro.models import build_model
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule
from repro.training.train_step import TrainConfig
from repro.training.trainer import CrashForTest, TrainerConfig, train


def test_checkpoint_roundtrip_exact():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,), jnp.int32), {"c": jnp.asarray(2.5)}]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        out, step = restore_checkpoint(d, tree)
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomicity_no_partial():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.zeros(4)})
        # a stale tmp dir from a crashed writer must not be visible
        os.makedirs(os.path.join(d, "step_00000002.tmp.999"))
        assert latest_step(d) == 1


def test_async_checkpointer_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            ck.save(s, {"x": jnp.full((4,), s)})
        ck.wait()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d))
        assert steps == [2, 3]
        out, _ = restore_checkpoint(d, {"x": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(out["x"]), 3.0)


def test_crash_restart_matches_uninterrupted():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, batch=2, seq_len=16)
    tcfg = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=30)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(CrashForTest):
            train(model, dcfg, tcfg, TrainerConfig(steps=20, ckpt_dir=d,
                                                   ckpt_every=5, crash_at=12), seed=0)
        resumed = train(model, dcfg, tcfg, TrainerConfig(steps=20, ckpt_dir=d,
                                                         ckpt_every=5), seed=0)
        assert resumed["start"] == 10
    ref = train(model, dcfg, tcfg, TrainerConfig(steps=20), seed=0)
    assert abs(ref["losses"][-1] - resumed["losses"][-1]) < 1e-4
    assert ref["losses"][-1] < ref["losses"][0]


def test_adamw_matches_numpy_reference():
    r = np.random.default_rng(0)
    p = {"w": jnp.asarray(r.standard_normal((4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(r.standard_normal((4, 3)), jnp.float32)}
    state = adamw_init(p)
    new_p, new_state = adamw_update(g, state, p, lr=0.1, b1=0.9, b2=0.95,
                                    eps=1e-8, weight_decay=0.0, grad_clip=1e9)
    # numpy reference (step 1)
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.05 * gn ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, atol=1e-5)


def test_grad_clip_scales_update():
    p = {"w": jnp.zeros((2,), jnp.float32)}
    g = {"w": jnp.asarray([3.0, 4.0])}        # norm 5
    st = adamw_init(p)
    p1, _ = adamw_update(g, st, p, lr=1.0, weight_decay=0.0, grad_clip=1.0)
    p2, _ = adamw_update(jax.tree.map(lambda x: x / 5.0, g), adamw_init(p), p,
                         lr=1.0, weight_decay=0.0, grad_clip=1e9)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), atol=1e-5)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.asarray(0), peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(jnp.asarray(10), peak_lr=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_schedule(jnp.asarray(100), peak_lr=1.0, warmup=10, total=100, floor=0.1))
    assert abs(end - 0.1) < 1e-6


def test_data_pipeline_deterministic():
    dcfg = DataConfig(vocab=128, batch=2, seq_len=16, seed=3)
    a = synthesize_batch(dcfg, 5)
    b = synthesize_batch(dcfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthesize_batch(dcfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted structure with learnable n-grams
    assert a["tokens"].shape == (2, 16) and a["labels"].shape == (2, 16)


def test_chunked_xent_matches_full():
    """The chunked-vocab-xent memory optimization is exact (loss + grads)."""
    import jax
    import jax.numpy as jnp
    from repro.models import RunCtx, build_model as _bm
    cfg = tiny_config("qwen2.5-3b")
    m = _bm(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (2, 24))),
             "labels": jnp.asarray(r.integers(0, cfg.vocab, (2, 24)))}
    ctx = RunCtx(mode="train", attn_backend="xla", moe_strategy="capacity",
                 block_q=8, block_kv=8)
    l0, _ = m.loss(params, batch, ctx)
    l1, _ = m.loss(params, batch, ctx, xent_chunk=7)
    assert abs(float(l0) - float(l1)) < 1e-4
    g0 = jax.grad(lambda p: m.loss(p, batch, ctx)[0])(params)
    g1 = jax.grad(lambda p: m.loss(p, batch, ctx, xent_chunk=7)[0])(params)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    assert err < 1e-4
