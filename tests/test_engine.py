"""Engine integration: greedy output == pure-model reference (with and
without page-pressure preemption), static mode, cancel, slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.engine import EngineConfig, InferenceEngine, sample_tokens
from repro.core.metrics import Request
from repro.models import RunCtx, build_model

CTX = RunCtx(attn_backend="xla", moe_strategy="dropless", block_q=128, block_kv=128)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_greedy(model, params, prompt, n):
    cache = model.init_cache(1, 128, jnp.float32, kind="dense")
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, cache, CTX)
    out = [int(jnp.argmax(lg[0]))]
    for i in range(n - 1):
        lg, cache = model.decode_step(params, jnp.asarray([[out[-1]]]), cache,
                                      jnp.asarray([len(prompt) + i], jnp.int32), CTX)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.mark.parametrize("num_pages", [10, 64])
def test_engine_matches_reference(setup, num_pages):
    cfg, model, params = setup
    r = np.random.default_rng(0)
    prompts = [r.integers(1, cfg.vocab, 10).astype(np.int32) for _ in range(5)]
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=3, page_size=8, num_pages=num_pages, max_seq=64,
        prefill_bucket=16, greedy=True))
    reqs = [Request(req_id=f"x{i}", prompt_tokens=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    eng.allocator.check_invariants()
    for req, p in zip(reqs, prompts):
        assert req.finished
        assert req.generated == _ref_greedy(model, params, p, 12)


def test_static_mode_completes(setup):
    cfg, model, params = setup
    r = np.random.default_rng(1)
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=64, max_seq=64,
        prefill_bucket=16, greedy=True, scheduler="static"))
    reqs = [Request(req_id=f"s{i}", prompt_tokens=r.integers(1, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=6) for i in range(5)]
    eng.generate(reqs)
    assert all(q.finished and len(q.generated) == 6 for q in reqs)


def test_cancel_frees_slot(setup):
    cfg, model, params = setup
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=1, page_size=8, num_pages=32, max_seq=64,
        prefill_bucket=16, greedy=True))
    a = Request(req_id="a", prompt_tokens=np.arange(1, 6, dtype=np.int32),
                max_new_tokens=50)
    b = Request(req_id="b", prompt_tokens=np.arange(1, 6, dtype=np.int32),
                max_new_tokens=4)
    eng.submit(a)
    eng.submit(b)
    for _ in range(3):
        eng.step()
    assert eng.cancel("a")
    steps = 0
    while eng.has_work() and steps < 100:
        eng.step()
        steps += 1
    assert b.finished and len(b.generated) == 4
    eng.allocator.check_invariants()


def test_sampling_top_p_mass():
    """Sampled token must lie within the smallest set of tokens whose
    cumulative probability reaches top_p."""
    r = np.random.default_rng(0)
    logits = jnp.asarray(r.standard_normal((64, 32)) * 3, jnp.float32)
    top_p, temp = 0.7, 0.8
    toks = sample_tokens(logits, jax.random.PRNGKey(0), temp, top_p, False)
    p = jax.nn.softmax(logits / temp, axis=-1)
    for i, t in enumerate(np.asarray(toks)):
        row = np.asarray(p[i])
        order = np.argsort(-row)
        keep = np.cumsum(row[order]) - row[order] < top_p
        nucleus = set(order[keep].tolist())
        assert int(t) in nucleus


def test_sampling_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16)), jnp.float32)
    toks = sample_tokens(logits, jax.random.PRNGKey(0), 0.5, 0.7, True)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(jnp.argmax(logits, -1)))
