"""Safety module (auth/rate-limit/content filter) + wire codecs."""
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.safety import (AuthError, Authenticator, ContentBlocked,
                               ContentFilter, RateLimited, TokenBucket)
from repro.core.serde import CODECS


def test_auth_roundtrip_and_rejection():
    a = Authenticator(secret=b"k")
    tok = a.issue("alice")
    assert a.verify(tok) == "alice"
    with pytest.raises(AuthError):
        a.verify(tok[:-2] + "zz")
    with pytest.raises(AuthError):
        a.verify("malformed")
    with pytest.raises(AuthError):
        Authenticator(secret=b"other").verify(tok)


def test_rate_limiter_enforces_rate():
    rl = TokenBucket(rate=10.0, burst=5.0)
    t = 0.0
    for _ in range(5):
        rl.check("u", now=t)
    with pytest.raises(RateLimited):
        rl.check("u", now=t)
    rl.check("u", now=t + 0.2)          # refilled 2 tokens
    rl.check("other", now=t)            # independent buckets


def test_content_filter():
    cf = ContentFilter(blocked={13, 666})
    cf.check([1, 2, 3])
    with pytest.raises(ContentBlocked):
        cf.check([1, 666, 3])


@pytest.mark.parametrize("codec_name", ["json", "binary"])
def test_codec_roundtrip(codec_name):
    c = CODECS[codec_name]
    raw = c.encode_request("rid-1", [1, 2, 3, 400], {"temperature": 0.3,
                                                     "top_p": 0.9,
                                                     "max_new_tokens": 17})
    rid, toks, params = c.decode_request(raw)
    assert rid == "rid-1" and toks == [1, 2, 3, 400]
    assert params["max_new_tokens"] == 17
    tok_raw = c.encode_token("rid-1", 42, 5, True)
    rid2, tok, idx, fin = c.decode_token(tok_raw)
    assert (rid2, tok, idx, fin) == ("rid-1", 42, 5, True)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 200_000), min_size=1, max_size=64),
       st.integers(0, 1_000_000), st.booleans())
def test_codec_roundtrip_hypothesis(tokens, tok, fin):
    for c in CODECS.values():
        raw = c.encode_request("x", tokens, {})
        _, t2, _ = c.decode_request(raw)
        assert t2 == tokens
        _, tok2, _, fin2 = c.decode_token(c.encode_token("x", tok, 0, fin))
        assert tok2 == tok and fin2 == fin


def test_binary_is_smaller_than_json():
    """The paper's serde claim: compact binary framing beats verbose JSON."""
    toks = list(range(100))
    j = CODECS["json"].encode_request("r", toks, {})
    b = CODECS["binary"].encode_request("r", toks, {})
    assert len(b) < len(j) / 2
    jt = CODECS["json"].encode_token("r", 5, 0, False)
    bt = CODECS["binary"].encode_token("r", 5, 0, False)
    assert len(bt) < len(jt) / 5
