"""Paged allocator property tests: refcount/ownership consistency, no leaks,
capacity arithmetic, COW discipline, LRU retirement — driven by random
alloc/free/share/COW traces (hypothesis when installed, plus a seeded
deterministic fuzz that always runs)."""
import random

import pytest

try:                                       # property tests need hypothesis;
    from hypothesis import given, settings, strategies as st
except ImportError:                        # deterministic tests run regardless
    given = settings = st = None

from repro.core.kv_cache import OutOfPages, PagedAllocator, PrefixCache


def test_basic_alloc_free():
    a = PagedAllocator(num_pages=17, page_size=4, max_pages_per_seq=8)
    assert a.free_pages == 16
    new = a.allocate(0, 9)          # 3 pages
    assert len(new) == 3 and a.free_pages == 13
    assert a.allocate(0, 10) == []  # still 3 pages
    assert len(a.allocate(0, 13)) == 1
    a.check_invariants()
    assert a.free(0) == 4
    assert a.free_pages == 16
    a.check_invariants()


def test_out_of_pages():
    a = PagedAllocator(num_pages=5, page_size=4, max_pages_per_seq=8)
    a.allocate(0, 12)               # 3 of 4 usable
    with pytest.raises(OutOfPages):
        a.allocate(1, 8)
    a.check_invariants()


def test_max_pages_per_seq():
    a = PagedAllocator(num_pages=64, page_size=4, max_pages_per_seq=2)
    with pytest.raises(OutOfPages):
        a.allocate(0, 12)


def test_can_allocate_enforces_max_pages_per_seq():
    """Regression: can_allocate used to ignore max_pages_per_seq, so the
    scheduler could admit a request that allocate() then rejected."""
    a = PagedAllocator(num_pages=64, page_size=4, max_pages_per_seq=2)
    assert not a.can_allocate(0, 12)      # allocate() would raise
    assert a.can_allocate(0, 8)
    a.allocate(0, 8)
    assert not a.can_allocate(0, 9)       # growth past the cap
    # agreement with allocate() across the boundary
    for n in range(1, 20):
        b = PagedAllocator(num_pages=64, page_size=4, max_pages_per_seq=2)
        ok = b.can_allocate(5, n)
        try:
            b.allocate(5, n)
            assert ok, n
        except OutOfPages:
            assert not ok, n


def test_share_refcounts_and_retirement():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=8)
    pages = a.allocate(0, 8)              # 2 exclusive pages
    a.share(1, pages)                     # both now shared
    assert all(a.refcount(p) == 2 for p in pages)
    a.check_invariants()
    a.free(0)
    assert all(a.refcount(p) == 1 for p in pages)
    # mark as prefix-cached: refcount 0 retires to LRU instead of freeing
    for p in pages:
        a.mark_cached(p)
    a.free(1)
    assert a.retired_pages == 2
    assert a.free_pages == 15             # retired pages still count as capacity
    # revival: share out of the LRU pool
    a.share(2, pages)
    assert a.retired_pages == 0 and all(a.refcount(p) == 1 for p in pages)
    a.check_invariants()


def test_cow_never_mutates_shared_page():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=8)
    pages = a.allocate(0, 8)
    a.share(1, pages)
    copies = a.ensure_exclusive(1, 0, 1)  # both blocks shared -> both copied
    assert len(copies) == 2
    for src, dst in copies:
        assert src in pages               # original untouched, still owned by 0
        assert a.refcount(src) == 1
        assert a.refcount(dst) == 1 and dst not in pages
    assert a.owned(0) == pages            # slot 0's mapping unchanged
    assert a.cow_copies == 2
    # exclusive uncached pages need no copy
    assert a.ensure_exclusive(1, 0, 1) == []
    a.check_invariants()


def test_cow_on_cached_page_even_when_refcount_one():
    """A trie-registered page must never be written even if only one slot
    references it — the cached content backs future prefix hits."""
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=8)
    pages = a.allocate(0, 4)
    a.mark_cached(pages[0])
    copies = a.ensure_exclusive(0, 0, 0)
    assert len(copies) == 1 and copies[0][0] == pages[0]
    assert a.retired_pages == 1           # original retired, content preserved
    a.check_invariants()


def test_cow_partial_failure_preserves_copies():
    """Regression: OutOfPages partway through ensure_exclusive used to drop
    the (src, dst) pairs of blocks already detached — their fresh pages would
    hold uninitialized KV. Callers pass a shared list that survives the abort
    and accumulates across retries."""
    a = PagedAllocator(num_pages=4, page_size=4, max_pages_per_seq=8)
    pages = a.allocate(0, 8)              # 2 pages; 1 page left free
    a.share(1, pages)
    copies = []
    with pytest.raises(OutOfPages):
        a.ensure_exclusive(1, 0, 1, copies=copies)
    assert len(copies) == 1               # first block detached before abort
    src, dst = copies[0]
    assert src == pages[0] and a.owned(1)[0] == dst and a.refcount(dst) == 1
    a.check_invariants()
    a.free(0)                             # pressure released
    a.ensure_exclusive(1, 0, 1, copies=copies)
    # block 1 became exclusive when slot 0 freed (no second copy needed) and
    # the pair from the failed attempt is still queued
    assert copies == [(src, dst)]
    assert a.owned(1) == [dst, pages[1]]
    a.check_invariants()


def test_eviction_only_takes_refcount_zero_pages():
    a = PagedAllocator(num_pages=5, page_size=4, max_pages_per_seq=8)
    evicted = []
    a.on_evict = evicted.append
    held = a.allocate(0, 8)               # 2 live pages
    cached = a.allocate(1, 8)             # 2 pages, then retired via cache
    for p in cached:
        a.mark_cached(p)
    a.free(1)
    assert a.retired_pages == 2
    new = a.allocate(2, 8)                # pool has only the 2 retired left
    assert sorted(new) == sorted(cached)  # reclaimed LRU pages, oldest first
    assert evicted == cached and a.evicted_pages == 2
    assert all(a.refcount(p) == 1 for p in held)
    a.check_invariants()
    with pytest.raises(OutOfPages):
        a.allocate(3, 4)                  # nothing refcount-0 left to evict


def test_truncate_exclusive_pages_return_to_free_list():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=8)
    a.allocate(0, 16)                     # 4 pages
    free_before = a.free_pages
    assert a.truncate(0, 2) == 2          # speculative-rollback shape
    assert len(a.owned(0)) == 2
    assert a.free_pages == free_before + 2
    assert a.truncate(0, 2) == 0          # idempotent at the target size
    a.check_invariants()


def test_truncate_shared_and_cached_pages():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=8)
    pages = a.allocate(0, 12)             # 3 pages
    a.share(1, pages)                     # slot 1 references all three
    a.mark_cached(pages[2])
    # rolling slot 1 back to 1 page must not free pages slot 0 still owns:
    # the shared tail pages just lose one reference; the cached page stays
    # cached (it still has a live reference via slot 0)
    assert a.truncate(1, 1) == 2
    assert a.owned(1) == [pages[0]]
    assert a.refcount(pages[0]) == 2      # still shared by both slots
    assert a.refcount(pages[1]) == 1 and a.refcount(pages[2]) == 1
    assert a.retired_pages == 0
    a.check_invariants()
    # when the cached page's last reference drops it retires, not frees
    assert a.truncate(0, 2) == 1
    assert a.retired_pages == 1
    a.check_invariants()


def test_page_table_row():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=4)
    a.allocate(3, 7)
    row = a.page_table_row(3)
    assert row.shape == (4,)
    assert (row[:2] > 0).all() and (row[2:] == 0).all()
    assert 0 not in a.owned(3)      # null page never handed out


# ---------------------------------------------------------------------------
# Refcount/COW/trie property suite. Ops model the engine's real call pattern:
# admit (lookup+share then allocate), feed (insert full prompt blocks into the
# trie), write (ensure_exclusive over a block range), rollback (truncate the
# page tail after a rejected speculative draft), release (free). The
# allocator invariants (sum of refcounts == ownership counts; referenced +
# free + retired == total - 1; cached pages live or retired) are re-checked
# after every op, plus: COW only ever detaches shared/cached pages and always
# yields fresh refcount-1 destinations; eviction only takes refcount-0 pages.
# Driven by hypothesis when installed, and always by a seeded fuzz below.
# ---------------------------------------------------------------------------

def _prompt(pid: int, n: int):
    # deterministic content per prompt id so equal pids share prefixes
    return [(pid * 97 + i) % 13 for i in range(n)]


def _run_refcount_trace(trace):
    ps = 4
    a = PagedAllocator(num_pages=24, page_size=ps, max_pages_per_seq=10)
    trie = PrefixCache(a)
    base_evict = a.on_evict

    def on_evict(page):
        assert a.refcount(page) == 0, "evicted a referenced page"
        base_evict(page)
    a.on_evict = on_evict

    slot_pid = {}
    for slot, op, pid, n in trace:
        if op == "admit" and slot not in slot_pid:
            tokens = _prompt(pid, n)
            shared = trie.lookup(tokens)[: a.max_pages_per_seq]
            try:
                a.share(slot, shared)
                a.allocate(slot, n)
                slot_pid[slot] = (pid, n)
            except OutOfPages:
                a.free(slot)              # admission failed: roll back shares
        elif op == "feed" and slot in slot_pid:
            spid, sn = slot_pid[slot]
            trie.insert(_prompt(spid, sn), a.owned(slot), sn // ps)
        elif op == "write" and slot in slot_pid:
            owned = a.owned(slot)
            if owned:
                lo = pid % len(owned)
                before = {p: a.refcount(p) for p in owned}
                cached_before = set(a._cached)
                try:
                    copies = a.ensure_exclusive(slot, lo, len(owned) - 1)
                except OutOfPages:
                    continue
                for src, dst in copies:
                    assert before[src] > 1 or src in cached_before, \
                        "COW detached an exclusive uncached page"
                    assert a.refcount(dst) == 1, "COW destination not fresh"
                    assert src != dst
                # the written range is now exclusively owned and uncached
                for p in a.owned(slot)[lo:]:
                    assert a.refcount(p) == 1 and p not in a._cached
        elif op == "rollback" and slot in slot_pid:
            # speculative-decode rollback: grow for draft tokens, then drop
            # the tail pages as if verify rejected the drafts. Shared pages
            # must only lose a reference, cached pages must retire (never
            # free), and the trie must never see the rolled-back pages.
            owned_before = list(a.owned(slot))
            keep = pid % (len(owned_before) + 1)
            shared_tail = [p for p in owned_before[keep:] if a.refcount(p) > 1]
            cached_tail = [p for p in owned_before[keep:]
                           if a.refcount(p) == 1 and p in a._cached]
            dropped = a.truncate(slot, keep)
            assert dropped == len(owned_before) - keep
            assert a.owned(slot) == owned_before[:keep]
            for p in shared_tail:
                assert a.refcount(p) >= 1, "shared page freed by rollback"
            for p in cached_tail:
                assert a.retired(p), "cached page not retired by rollback"
        elif op == "release" and slot in slot_pid:
            a.free(slot)
            del slot_pid[slot]
        a.check_invariants()

    for slot in list(slot_pid):
        a.free(slot)
    a.check_invariants()
    assert not a._ref, "references leaked after all slots freed"
    assert len(a._free) + len(a._lru) == a.num_pages - 1


def test_refcount_cow_trie_seeded_fuzz():
    """Seeded stand-in for the hypothesis suite so the invariants are
    exercised even where hypothesis is not installed."""
    for seed in range(8):
        rng = random.Random(seed)
        trace = [(rng.randrange(5),
                  rng.choice(["admit", "feed", "write", "rollback", "release"]),
                  rng.randrange(4), rng.randint(1, 40))
                 for _ in range(120)]
        _run_refcount_trace(trace)


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 40),
                              st.booleans()), min_size=1, max_size=60))
    def test_random_traces_keep_invariants(trace):
        a = PagedAllocator(num_pages=24, page_size=4, max_pages_per_seq=10)
        for slot, tokens, do_free in trace:
            if do_free:
                a.free(slot)
            else:
                try:
                    a.allocate(slot, tokens)
                except OutOfPages:
                    pass
            a.check_invariants()

    _OPS = st.lists(
        st.tuples(st.integers(0, 4),          # slot
                  st.sampled_from(["admit", "feed", "write", "rollback",
                                   "release"]),
                  st.integers(0, 3),          # prompt id (content class)
                  st.integers(1, 40)),        # token count
        min_size=1, max_size=80)

    @settings(max_examples=30, deadline=None)
    @given(_OPS)
    def test_refcount_cow_trie_traces_keep_invariants(trace):
        _run_refcount_trace(trace)


# ---------------------------------------------------------------------------
# End-to-end chaos property (DESIGN.md §5): an injected replica crash with
# cancellations racing the automatic failover must leak zero KV pages on
# every allocator — the dead replica's included — and no request may observe
# an event after its terminal one.
# ---------------------------------------------------------------------------

def test_crash_cancel_failover_leaks_nothing():
    import threading
    import time

    import jax
    import numpy as np

    from repro.configs import tiny_config
    from repro.core import (EngineConfig, FaultInjector, FaultPlan,
                            InferenceEngine, Replica, ReplicaRouter,
                            RouterConfig)
    from repro.core.metrics import Request
    from repro.models import build_model

    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def engine():
        return InferenceEngine(model, params, EngineConfig(
            max_slots=4, page_size=8, num_pages=128, max_seq=128,
            prefill_bucket=16, greedy=True))

    inj = FaultInjector(FaultPlan().crash("x0", 0.25)).start()
    r0 = Replica("x0", engine(), injector=inj).start()
    r1 = Replica("x1", engine()).start()
    router = ReplicaRouter([r0, r1], RouterConfig(policy="round_robin",
                                                  monitor_interval_s=0.01))
    router.start_monitor()

    events = {}                  # rid -> [finished flags, in delivery order]
    lock = threading.Lock()

    def on_event(ev):
        with lock:
            events.setdefault(ev.request.req_id, []).append(ev.finished)

    rng = np.random.default_rng(5)
    reqs, targets = [], []
    for i in range(6):
        req = Request(req_id=f"cc{i}",
                      prompt_tokens=rng.integers(1, cfg.vocab, 12,
                                                 dtype=np.int64).astype(np.int32),
                      max_new_tokens=48)
        reqs.append(req)
        targets.append(router.submit(req, on_event))

    # cancel two requests routed to the survivor while the crash lands on x0
    time.sleep(0.1)
    cancelled = set()
    for req, target in zip(reqs, targets):
        if target.replica_id == "x1" and len(cancelled) < 2:
            target.cancel(req.req_id)
            cancelled.add(req.req_id)

    deadline = time.monotonic() + 60
    live = [r for r in reqs if r.req_id not in cancelled]
    while (not all(r.finished for r in live)
           and time.monotonic() < deadline):
        time.sleep(0.02)
    router.stop_monitor()
    for r in (r0, r1):
        r.stop()

    assert all(r.finished for r in live), "chaos run did not converge"
    assert all(r.error is None for r in live)
    assert all(len(r.generated) == 48 for r in live)
    assert router.auto_failovers == 1 and router.manual_failovers == 0
    assert [e.reason for e in router.failover_events] == ["crash"]
    # terminal-guard property: nothing delivered after the terminal event
    for rid, flags in events.items():
        if True in flags:
            assert flags.index(True) == len(flags) - 1, \
                f"{rid} observed events after its terminal"
    # zero-leak property: both allocators fully drained, invariants hold
    for r in (r0, r1):
        r.engine.allocator.check_invariants()
        assert r.engine.allocator.live_pages == 0, \
            f"{r.replica_id} leaked {r.engine.allocator.live_pages} pages"
