"""Paged allocator property tests: no double-ownership, no leaks, capacity
arithmetic — driven by random alloc/free traces (hypothesis)."""
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.kv_cache import OutOfPages, PagedAllocator


def test_basic_alloc_free():
    a = PagedAllocator(num_pages=17, page_size=4, max_pages_per_seq=8)
    assert a.free_pages == 16
    new = a.allocate(0, 9)          # 3 pages
    assert len(new) == 3 and a.free_pages == 13
    assert a.allocate(0, 10) == []  # still 3 pages
    assert len(a.allocate(0, 13)) == 1
    a.check_invariants()
    assert a.free(0) == 4
    assert a.free_pages == 16
    a.check_invariants()


def test_out_of_pages():
    a = PagedAllocator(num_pages=5, page_size=4, max_pages_per_seq=8)
    a.allocate(0, 12)               # 3 of 4 usable
    with pytest.raises(OutOfPages):
        a.allocate(1, 8)
    a.check_invariants()


def test_max_pages_per_seq():
    a = PagedAllocator(num_pages=64, page_size=4, max_pages_per_seq=2)
    with pytest.raises(OutOfPages):
        a.allocate(0, 12)


def test_page_table_row():
    a = PagedAllocator(num_pages=16, page_size=4, max_pages_per_seq=4)
    a.allocate(3, 7)
    row = a.page_table_row(3)
    assert row.shape == (4,)
    assert (row[:2] > 0).all() and (row[2:] == 0).all()
    assert 0 not in a.owned(3)      # null page never handed out


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 40),
                          st.booleans()), min_size=1, max_size=60))
def test_random_traces_keep_invariants(trace):
    a = PagedAllocator(num_pages=24, page_size=4, max_pages_per_seq=10)
    for slot, tokens, do_free in trace:
        if do_free:
            a.free(slot)
        else:
            try:
                a.allocate(slot, tokens)
            except OutOfPages:
                pass
        a.check_invariants()
