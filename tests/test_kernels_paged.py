"""Paged-attention decode kernel vs oracle: ragged lengths, GQA groups,
sliding window, page-size sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention, paged_attention_reference


@pytest.mark.parametrize("H,Hkv,D", [(8, 2, 16), (4, 4, 32), (8, 1, 64)])
@pytest.mark.parametrize("page_size", [4, 8])
@pytest.mark.parametrize("window", [0, 9])
def test_paged_vs_reference(rng, H, Hkv, D, page_size, window):
    B, P, maxp = 3, 24, 5
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, page_size, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page_size, Hkv, D)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, P, (B, maxp)), jnp.int32)
    lengths = jnp.asarray([1, page_size * 2 + 3, maxp * page_size], jnp.int32)
    ref = paged_attention_reference(q, kp, vp, pt, lengths, window=window)
    out = paged_attention(q, kp, vp, pt, lengths, window=window,
                          backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_paged_bf16(rng):
    B, H, Hkv, D, P, ps, maxp = 2, 4, 2, 32, 16, 8, 4
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.bfloat16)
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.bfloat16)
    pt = jnp.asarray(rng.integers(0, P, (B, maxp)), jnp.int32)
    lengths = jnp.asarray([7, 30], jnp.int32)
    ref = paged_attention_reference(q, kp, vp, pt, lengths)
    out = paged_attention(q, kp, vp, pt, lengths, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2)


def test_softcap(rng):
    B, H, Hkv, D, P, ps, maxp = 2, 4, 2, 16, 8, 4, 3
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    pt = jnp.asarray(rng.integers(0, P, (B, maxp)), jnp.int32)
    lengths = jnp.asarray([5, 12], jnp.int32)
    ref = paged_attention_reference(q, kp, vp, pt, lengths, softcap=30.0)
    out = paged_attention(q, kp, vp, pt, lengths, softcap=30.0,
                          backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
