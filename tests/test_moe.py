"""MoE: strategy equivalence (capacity == tp_shardmap == ep_shardmap on a
mesh), dropless exactness, router properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.configs import tiny_config
from repro.models import RunCtx, build_model
from repro.models.moe import (capacity_combine, capacity_dispatch, moe_sublayer,
                              router_topk)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = tiny_config("mixtral-8x7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    p = jax.tree.map(lambda x: x[0], params["groups"][0]["layers"][0]["moe"])
    h = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)),
                    jnp.float32)
    return cfg, p, h


def test_strategies_agree_on_mesh(moe_setup):
    cfg, p, h = moe_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    outs = {}
    for strat in ["capacity", "tp_shardmap", "ep_shardmap"]:
        ctx = RunCtx(moe_strategy=strat, mesh=mesh if "shardmap" in strat else None)
        with mesh:
            y, aux = moe_sublayer(p, h, cfg, ctx)
        outs[strat] = np.asarray(y)
    np.testing.assert_allclose(outs["capacity"], outs["tp_shardmap"], atol=1e-5)
    np.testing.assert_allclose(outs["capacity"], outs["ep_shardmap"], atol=1e-5)


def test_dropless_weights_sum(moe_setup):
    """Dropless output is a convex combination of expert outputs — compare
    against a brute-force dense evaluation."""
    cfg, p, h = moe_setup
    ctx = RunCtx(moe_strategy="dropless")
    y, aux = moe_sublayer(p, h, cfg, ctx)
    xf = h.reshape(-1, h.shape[-1])
    topw, topi, _ = router_topk(xf, p["router"], cfg.moe.top_k)
    dense = jnp.einsum("ecd,edf->ecf", xf[None].repeat(cfg.moe.num_experts, 0), p["wg"])
    h1 = dense
    h2 = jnp.einsum("ecd,edf->ecf", xf[None].repeat(cfg.moe.num_experts, 0), p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h2, p["wd"])   # (E,T,d)
    expect = jnp.zeros_like(xf)
    for kk in range(cfg.moe.top_k):
        expect = expect + topw[:, kk, None] * jnp.take_along_axis(
            ye, topi[:, kk][None, :, None], axis=0)[0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, h.shape[-1])),
                               np.asarray(expect), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(2, 8), st.integers(1, 4))
def test_router_topk_properties(T, E, K):
    K = min(K, E)
    r = np.random.default_rng(T * E + K)
    xf = jnp.asarray(r.standard_normal((T, 8)), jnp.float32)
    w = jnp.asarray(r.standard_normal((8, E)), jnp.float32)
    topw, topi, aux = router_topk(xf, w, K)
    assert topw.shape == (T, K) and topi.shape == (T, K)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, atol=1e-5)
    assert bool(jnp.all(topw >= 0))
    assert bool(jnp.all((topi >= 0) & (topi < E)))
    # distinct experts per token
    for row in np.asarray(topi):
        assert len(set(row.tolist())) == K
    # E * sum f*P ~= 1 at uniform routing, rises with imbalance; the exact
    # >=1 bound only holds for top-1, so assert the sane range.
    assert 0.9 <= float(aux) < E + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(2, 6), st.integers(1, 3), st.integers(2, 16))
def test_capacity_dispatch_combine_identity(T, E, K, cap):
    """With identity expert fn, dispatch+combine returns sum_k w_k * x for
    tokens whose slots fit; dropped slots contribute 0."""
    K = min(K, E)
    r = np.random.default_rng(T + E + K + cap)
    xf = jnp.asarray(r.standard_normal((T, 4)), jnp.float32)
    topi = jnp.asarray(r.integers(0, E, (T, K)), jnp.int32)
    topw = jnp.ones((T, K), jnp.float32) / K
    ebuf, info = capacity_dispatch(xf, topi, E, cap)
    y = capacity_combine(ebuf, info, topw)
    keep = np.asarray(info[2]).reshape(T, K)
    expect = (np.asarray(xf)[:, None, :] * keep[:, :, None]).sum(1) / K
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-5)
