"""Roofline machinery: HLO collective parsing, term math, local-bytes
sharding arithmetic, workload generator stats."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data.workload import WorkloadSpec, sample_workload
from repro.launch.roofline import model_flops, parse_collective_bytes, roofline

HLO = """
ENTRY %main {
  %p0 = bf16[16,512,2048]{2,1,0} parameter(0)
  %ag = bf16[16,512,2048]{2,1,0} all-gather(%p0), dimensions={1}
  %ar.1 = f32[1024,688]{1,0} all-reduce(%x), to_apply=%sum
  %a2a = bf16[16,8,6144]{2,1,0} all-to-all(%buf), dimensions={0}
  %rs-start = f32[64]{0} reduce-scatter-start(%g)
  %agd = bf16[4,4]{1,0} all-gather-done(%h)
  %cp = u32[8,128]{1,0} collective-permute(%q)
  %dot = f32[8,8]{1,0} dot(%a, %b)
}
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"] == 16 * 512 * 2048 * 2          # -done not counted twice
    assert out["all-reduce"] == 1024 * 688 * 4
    assert out["all-to-all"] == 16 * 8 * 6144 * 2
    assert out["reduce-scatter"] == 64 * 4                   # -start counted
    assert out["collective-permute"] == 8 * 128 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "all-to-all",
                                "reduce-scatter", "collective-permute"))


def test_roofline_terms_and_dominant():
    t = roofline(197e12, 819e9, 25e9)     # 1s compute, 1s memory, 0.5s coll
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 0.5) < 1e-9
    t2 = roofline(1e12, 1e9, 100e9)
    assert t2.dominant == "collective"
    assert t2.bound_s == t2.collective_s


def test_model_flops_conventions():
    from repro.configs import SHAPES, get_config
    cfg = get_config("mixtral-8x7b")
    n_active = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6.0 * n_active * 256 * 4096)
    assert model_flops(cfg, SHAPES["decode_32k"]) == pytest.approx(
        2.0 * n_active * 128)


def test_local_bytes_respects_sharding():
    import jax
    from repro.launch.roofline import local_bytes

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 2))

    tree = {"w": jax.ShapeDtypeStruct((8, 16), np.dtype("float32"))}
    full = local_bytes(tree, {"w": P(None, None)}, FakeMesh())
    half = local_bytes(tree, {"w": P(None, "model")}, FakeMesh())
    eighth = local_bytes(tree, {"w": P("data", "model")}, FakeMesh())
    assert full == 8 * 16 * 4
    assert half == full // 2
    assert eighth == full // 8


def test_workload_stats():
    prompts, outs = sample_workload(WorkloadSpec(n_requests=200, vocab=1000, seed=1))
    lens = np.array([len(p) for p in prompts])
    assert 50 < np.median(lens) < 450         # OpenOrca-ish median around 150
    assert lens.max() <= 2048 and lens.min() >= 2
    assert all(2 <= o <= 512 for o in outs)
    # deterministic per seed
    p2, _ = sample_workload(WorkloadSpec(n_requests=200, vocab=1000, seed=1))
    assert all(np.array_equal(a, b) for a, b in zip(prompts, p2))
