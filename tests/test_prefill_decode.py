"""Serving invariants: prefill+decode == full forward (per family), and paged
decode == dense decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.models import RunCtx, build_model

ARCHS = ["qwen2.5-3b", "gemma2-27b", "phi3-mini-3.8b", "mamba2-1.3b",
         "jamba-v0.1-52b", "mixtral-8x7b", "deepseek-moe-16b",
         "seamless-m4t-large-v2", "phi-3-vision-4.2b"]

CTX = RunCtx(attn_backend="xla", moe_strategy="dropless", block_q=8, block_kv=8)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S, gen = 2, 20, 6
    r = np.random.default_rng(1)
    toks = jnp.asarray(r.integers(0, cfg.vocab, (B, S + gen)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(r.standard_normal((B, 12, cfg.d_model)), jnp.float32)
    if cfg.vision is not None:
        batch["patches"] = jnp.asarray(
            r.standard_normal((B, cfg.vision.n_patches, cfg.vision.d_patch)), jnp.float32)
    offset = cfg.vision.n_patches if cfg.vision is not None else 0
    logits_full, _ = model.forward(params, batch, CTX)

    cache = model.init_cache(B, S + gen + offset, jnp.float32, kind="dense",
                             memory_len=12 if cfg.encoder is not None else 0)
    bp = dict(batch)
    bp["tokens"] = toks[:, :S]
    lg, cache = model.prefill(params, bp, cache, CTX)
    errs = [float(jnp.max(jnp.abs(lg - logits_full[:, S - 1 + offset])))]
    for i in range(gen):
        pos = jnp.full((B,), S + i + offset, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, S + i:S + i + 1], cache, pos, CTX)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, S + i + offset]))))
    assert max(errs) < 2e-3, errs


def test_paged_equals_dense_decode():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S, gen, W, ps = 2, 24, 6, 32, 8
    r = np.random.default_rng(1)
    toks = jnp.asarray(r.integers(0, cfg.vocab, (B, S + gen)), jnp.int32)
    dense = model.init_cache(B, W, jnp.float32, kind="dense")
    lg, dense = model.prefill(params, {"tokens": toks[:, :S]}, dense, CTX)
    maxp = W // ps
    paged = model.init_cache(B, W, jnp.float32, kind="paged", page_size=ps,
                             num_pages=B * maxp + 1)
    pt = jnp.asarray([[b * maxp + i for i in range(maxp)] for b in range(B)], jnp.int32)
    for g in range(len(paged["groups"])):
        for pos in range(len(paged["groups"][g])):
            pc, dc = paged["groups"][g][pos], dense["groups"][g][pos]
            if "attn" not in pc:
                continue
            k, v = dc["attn"]["k"], dc["attn"]["v"]
            R, npg = k.shape[0], W // ps
            for name, src in (("kp", k), ("vp", v)):
                buf = pc["attn"][name]
                for b in range(B):
                    buf = buf.at[:, pt[b][:npg]].set(
                        src[:, b].reshape(R, npg, ps, *src.shape[3:]))
                pc["attn"][name] = buf
    cd, cp, errs = dense, paged, []
    for i in range(gen):
        pos = jnp.full((B,), S + i, jnp.int32)
        ld, cd = model.decode_step(params, toks[:, S + i:S + i + 1], cd, pos, CTX)
        lp, cp = model.decode_step(params, toks[:, S + i:S + i + 1], cp, pos, CTX,
                                   page_table=pt, lengths=pos + 1)
        errs.append(float(jnp.max(jnp.abs(ld - lp))))
    assert max(errs) < 1e-4, errs


def test_sliding_window_ring_buffer_decode():
    """gemma-family: local layers with W << context still decode correctly
    (ring buffer) — compare against a model with full-size windows."""
    cfg = tiny_config("gemma2-27b", seq_len=64)
    assert cfg.sliding_window > 0
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    B, S = 1, 40
    r = np.random.default_rng(3)
    toks = jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits_full, _ = model.forward(params, {"tokens": toks}, CTX)
    # decode from scratch token by token (prefill of 1 token, then decode)
    cache = model.init_cache(B, S, jnp.float32, kind="dense")
    lg, cache = model.prefill(params, {"tokens": toks[:, :1]}, cache, CTX)
    errs = []
    for i in range(1, S):
        pos = jnp.full((B,), i, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, i:i + 1], cache, pos, CTX)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, i]))))
    assert max(errs) < 2e-3, max(errs)
