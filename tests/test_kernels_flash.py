"""Flash-attention kernel: shape/dtype sweep vs the pure-jnp oracle
(pallas in interpret mode + the chunked-xla path)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, mha_chunked, mha_reference

CASES = [
    # B, Sq, Skv, H, Hkv, D, causal, window, softcap, q_offset
    (2, 64, 64, 4, 2, 16, True, 0, 0.0, 0),
    (1, 128, 128, 8, 8, 32, True, 0, 0.0, 0),       # MHA
    (2, 64, 64, 4, 1, 16, True, 0, 0.0, 0),         # MQA
    (1, 96, 96, 4, 2, 64, True, 32, 0.0, 0),        # sliding window
    (1, 64, 64, 4, 4, 16, True, 0, 50.0, 0),        # softcap (gemma2)
    (2, 32, 96, 2, 2, 16, True, 0, 0.0, 64),        # chunked-prefill offset
    (2, 48, 48, 4, 2, 16, False, 0, 0.0, 0),        # encoder (non-causal)
    (1, 80, 80, 4, 2, 16, True, 16, 30.0, 0),       # window + softcap
]


def _mk(rng, *shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_reference(rng, case, dtype):
    B, Sq, Skv, H, Hkv, D, causal, window, softcap, qoff = case
    q = _mk(rng, B, Sq, H, D, dtype=dtype)
    k = _mk(rng, B, Skv, Hkv, D, dtype=dtype)
    v = _mk(rng, B, Skv, Hkv, D, dtype=dtype)
    ref = mha_reference(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window,
                        softcap=softcap, q_offset=qoff)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap,
                          q_offset=qoff, block_q=16, block_kv=32,
                          backend="pallas", interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_reference(rng, case):
    B, Sq, Skv, H, Hkv, D, causal, window, softcap, qoff = case
    q = _mk(rng, B, Sq, H, D, dtype=jnp.float32)
    k = _mk(rng, B, Skv, Hkv, D, dtype=jnp.float32)
    v = _mk(rng, B, Skv, Hkv, D, dtype=jnp.float32)
    ref = mha_reference(q, k, v, causal=causal, window=window, softcap=softcap,
                        q_offset=qoff)
    out = mha_chunked(q, k, v, causal=causal, window=window, softcap=softcap,
                      q_offset=qoff, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_unrolled_equals_scanned(rng):
    q = _mk(rng, 2, 64, 4, 16, dtype=jnp.float32)
    k = _mk(rng, 2, 64, 2, 16, dtype=jnp.float32)
    v = _mk(rng, 2, 64, 2, 16, dtype=jnp.float32)
    a = mha_chunked(q, k, v, block_q=16, block_kv=16, unroll=False)
    b = mha_chunked(q, k, v, block_q=16, block_kv=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_ragged_block_sizes(rng):
    """Sq/Skv not divisible by the block sizes (padding path)."""
    q = _mk(rng, 1, 50, 4, 16, dtype=jnp.float32)
    k = _mk(rng, 1, 70, 2, 16, dtype=jnp.float32)
    v = _mk(rng, 1, 70, 2, 16, dtype=jnp.float32)
    ref = mha_reference(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_kv=32,
                          backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
