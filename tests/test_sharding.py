"""Logical-axis sharding rules: divisibility fallback, axis-reuse exclusion,
priority ordering (kv_heads over kv_seq) — property-tested."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import make_rules, param_partition_specs, partition_spec
from repro.launch.mesh import make_dev_mesh
from repro.models.params import param_specs


@pytest.fixture(scope="module")
def mesh():
    return make_dev_mesh(1, 1)


class FakeMesh:
    """Shape-only stand-in (no devices needed for spec computation)."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


M16 = FakeMesh({"data": 16, "model": 16})
RULES = make_rules("serve", moe="ep")


def test_divisible_heads_shard():
    spec = partition_spec((4096, 32, 128), ("embed", "heads", "head_dim"), M16, RULES)
    assert spec == P(None, "model", None)


def test_indivisible_kv_heads_fall_back_to_seq():
    # qwen cache: kv=2 can't shard 16-way -> kv_seq takes the model axis
    spec = partition_spec((36, 128, 32768, 2, 128),
                          ("layers", "batch", "kv_seq", "kv_heads", None), M16, RULES)
    assert spec == P(None, "data", "model", None, None)


def test_priority_kv_heads_beats_kv_seq():
    spec = partition_spec((46, 128, 32768, 16, 128),
                          ("layers", "batch", "kv_seq", "kv_heads", None), M16, RULES)
    assert spec == P(None, "data", None, "model", None)


def test_axis_never_reused():
    rules = make_rules("train")
    spec = partition_spec((16, 2048, 11008), ("experts", "embed", "mlp"),
                          M16, make_rules("serve", moe="ep"))
    used = [a for a in spec if a is not None]
    assert len(set(used)) == len(used)


def test_multi_pod_batch_uses_both_axes():
    mesh3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = make_rules("train", multi_pod=True)
    spec = partition_spec((256, 4096), ("batch", "seq"), mesh3, rules)
    assert spec[0] == ("pod", "data")


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 512), st.integers(1, 64), st.integers(1, 64))
def test_spec_always_divides(dim0, dim1, dim2):
    spec = partition_spec((dim0, dim1, dim2), ("batch", "heads", "mlp"), M16, RULES)
    sizes = {"data": 16, "model": 16}
    for d, entry in zip((dim0, dim1, dim2), spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([sizes[a] for a in axes]))
        assert d % prod == 0


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "dbrx-132b", "mamba2-1.3b",
                                  "jamba-v0.1-52b", "seamless-m4t-large-v2"])
def test_param_specs_build_for_archs(arch):
    cfg = get_config(arch)
    tree = param_partition_specs(param_specs(cfg), M16, RULES)
    for spec in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(spec, P)
