"""Router + gateway integration: policies, dynamic blueprint, failover with
mid-stream resume, hedging, auth rejection end-to-end."""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core import EngineConfig, Gateway, InferenceEngine, Replica, ReplicaRouter, RouterConfig, scale_gateway_config
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.metrics import Request
from repro.core.safety import Authenticator
from repro.data.workload import WorkloadSpec, sample_workload


@pytest.fixture(scope="module")
def model_setup():
    cfg = tiny_config("qwen2.5-3b")
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _replica(model, params, rid, klass="default", **kw):
    ekw = dict(max_slots=4, page_size=8, num_pages=128, max_seq=128,
               prefill_bucket=16, greedy=True)
    ekw.update(kw)
    return Replica(rid, InferenceEngine(model, params, EngineConfig(**ekw)),
                   klass=klass).start()


def test_least_loaded_spreads(model_setup):
    cfg, model, params = model_setup
    reps = [_replica(model, params, f"r{i}") for i in range(3)]
    router = ReplicaRouter(reps, RouterConfig(policy="round_robin"))
    seen = set()
    for i in range(6):
        r = router.select()
        seen.add(r.replica_id)
        router._rr += 0
    assert seen == {"r0", "r1", "r2"}
    for r in reps:
        r.stop()


def test_dynamic_blueprint_policy(model_setup):
    """Paper §6: < threshold -> high_tp class; >= threshold -> high_replica."""
    cfg, model, params = model_setup
    tp_rep = _replica(model, params, "bigtp", klass="high_tp")
    small = [_replica(model, params, f"small{i}", klass="high_replica")
             for i in range(2)]
    router = ReplicaRouter([tp_rep] + small,
                           RouterConfig(policy="dynamic", dynamic_threshold=4))
    router._live = 0
    assert router.select().klass == "high_tp"
    router._live = 10
    assert router.select().klass == "high_replica"
    for r in [tp_rep] + small:
        r.stop()


def test_kill_surrenders_inbox_requests():
    # a request submitted just before the failure sits in the inbox, not yet
    # moved to the engine — kill() must surrender it with the in-flight ones
    # or the client waits out its full timeout (failover race)
    class _EngineStub:
        pass

    rep = Replica("k0", _EngineStub())       # thread never started
    r = Request(req_id="k", prompt_tokens=np.arange(1, 4, dtype=np.int32))
    rep.submit(r, lambda ev: None)
    orphans = rep.kill()
    assert [o[0].req_id for o in orphans] == ["k"]


def test_failover_resumes_inflight(model_setup):
    cfg, model, params = model_setup

    async def main():
        reps = [_replica(model, params, f"f{i}") for i in range(2)]
        router = ReplicaRouter(reps, RouterConfig(policy="round_robin"))
        gw = Gateway(router, scale_gateway_config())
        prompts, _ = sample_workload(WorkloadSpec(n_requests=8, vocab=cfg.vocab,
                                                  scale=0.05, seed=2))

        async def killer():
            await asyncio.sleep(0.4)
            router.handle_failure(reps[0])

        res, _ = await asyncio.gather(
            run_workload(gw, prompts, concurrency=4, max_new_tokens=12, timeout_s=60),
            killer())
        merge_engine_timestamps(res.requests, gw)
        for r in reps:
            r.stop()
        return res, router

    res, router = asyncio.run(main())
    assert all(r.finished for r in res.requests)
    assert all(len(r.generated) == 12 for r in res.requests)


def test_gateway_auth_rejection(model_setup):
    cfg, model, params = model_setup

    async def main():
        rep = _replica(model, params, "a0")
        router = ReplicaRouter([rep])
        auth = Authenticator(secret=b"s3cret")
        gw = Gateway(router, scale_gateway_config(), auth=auth, require_auth=True)
        prompts = [np.arange(1, 8, dtype=np.int32)] * 2
        ok = await run_workload(gw, prompts, concurrency=2, max_new_tokens=4,
                                auth_token=auth.issue("bob"))
        bad = await run_workload(gw, prompts, concurrency=2, max_new_tokens=4,
                                 auth_token="bob:forged")
        rep.stop()
        return ok, bad

    ok, bad = asyncio.run(main())
    assert all(r.finished for r in ok.requests)
    assert all(r.error == "rejected" for r in bad.requests)


def test_hedging_straggler(model_setup):
    """A slow replica (large host overhead) gets hedged to a fast one."""
    cfg, model, params = model_setup
    slow = _replica(model, params, "slow", host_overhead_s=0.5)
    fast = _replica(model, params, "fast")
    router = ReplicaRouter([slow, fast],
                           RouterConfig(policy="round_robin", hedge_after_s=0.3))
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    req = Request(req_id="h1", prompt_tokens=np.arange(1, 8, dtype=np.int32),
                  max_new_tokens=4)
    # force primary = slow (round robin starts at index 0)
    router.submit(req, on_event, replica=slow)
    import time
    deadline = time.time() + 20
    while "req" not in done and time.time() < deadline:
        time.sleep(0.05)
    slow.stop()
    fast.stop()
    assert "req" in done
    assert router.sink.snapshot().get("hedges", 0) >= 1


def test_elastic_add_remove(model_setup):
    cfg, model, params = model_setup
    r0 = _replica(model, params, "e0")
    router = ReplicaRouter([r0])
    r1 = _replica(model, params, "e1")
    router.add_replica(r1)
    assert len(router.replicas) == 2
    router.remove_replica("e0")
    assert [r.replica_id for r in router.replicas] == ["e1"]
    assert router.select().replica_id == "e1"
    r0.stop()
    r1.stop()
