"""Speculative decoding (DESIGN.md §3): prompt-lookup drafting, multi-token
verify (greedy bit-identity + rejection-sampling exactness), KV rollback
composition with preemption and the prefix cache, and SSM gating."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.metrics import Request
from repro.core.spec import PromptLookupDraft, target_probs, verify_draft
from repro.models import build_model

ARCH = "qwen2.5-3b"


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _repetitive_prompts(vocab: int, n: int, seed: int = 3):
    """Extractive/boilerplate-shaped prompts (the spec-friendly traffic)."""
    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(n):
        if i % 2 == 0:
            passage = rng.integers(1, vocab, 12)
            query = rng.integers(1, vocab, 4)
            prompts.append(np.concatenate([passage, query, passage]).astype(np.int32))
        else:
            motif = rng.integers(1, vocab, 4)
            prompts.append(np.tile(motif, 7).astype(np.int32))
    return prompts


def _gen(model, params, prompts, *, spec: bool, max_new: int = 24, **kw):
    defaults = dict(max_slots=4, page_size=4, num_pages=256, max_seq=128,
                    prefill_bucket=8, greedy=True)
    defaults.update(kw)
    eng = InferenceEngine(model, params, EngineConfig(
        enable_speculative=spec, spec_k=4, **defaults))
    reqs = [Request(req_id=f"{spec}-{kw.get('num_pages', 0)}-{i}",
                    prompt_tokens=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    return eng, [r.generated for r in reqs]


# ------------------------------------------------------------ draft source
def test_prompt_lookup_continues_cycle():
    ds = PromptLookupDraft(ngram_max=3, ngram_min=1)
    assert ds.propose([1, 2, 3, 1, 2, 3, 1, 2], 5) == [3, 1, 2, 3, 1]


def test_prompt_lookup_extends_runs_periodically():
    ds = PromptLookupDraft()
    assert ds.propose([7, 7, 7, 7], 4) == [7, 7, 7, 7]


def test_prompt_lookup_prefers_most_recent_match():
    ds = PromptLookupDraft(ngram_max=3, ngram_min=1)
    # [9, 1] occurs twice; the draft continues the most recent occurrence
    draft = ds.propose([9, 1, 5, 9, 1, 7, 9, 1], 3)
    assert draft[0] == 7


def test_prompt_lookup_no_match_and_empty_inputs():
    ds = PromptLookupDraft()
    assert ds.propose([1, 2, 3, 4], 4) == []
    assert ds.propose([1, 2, 3, 4], 0) == []
    assert ds.propose([1], 4) == []
    assert ds.propose([], 4) == []


def test_prompt_lookup_longer_ngram_wins():
    ds = PromptLookupDraft(ngram_max=2, ngram_min=1)
    # 2-gram [5, 6] matches at position 0 -> draft starts with 8; a 1-gram
    # [6] match alone (position 4) would have drafted 9 instead
    assert ds.propose([5, 6, 8, 2, 6, 9, 5, 6], 1) == [8]


# ------------------------------------------------------------ verify_draft
def _mk_logits(rows):
    """rows: list of per-position argmax token ids -> (1, C, V) logits."""
    V = 16
    C = len(rows)
    logits = np.full((1, C, V), -3.0, np.float32)
    for j, t in enumerate(rows):
        logits[0, j, t] = 5.0
    return jnp.asarray(logits)


def test_verify_greedy_full_acceptance_emits_bonus():
    # model's argmax at positions 0..2 = [4, 5, 6]; drafts [4, 5] match
    logits = _mk_logits([4, 5, 6])
    tokens = jnp.asarray([[9, 4, 5]], jnp.int32)      # [last, d1, d2]
    n_acc, out = verify_draft(logits, tokens, jnp.asarray([3]),
                              jax.random.PRNGKey(0), 0.7, 0.9, greedy=True)
    assert int(n_acc[0]) == 2 and int(out[0]) == 6    # bonus token


def test_verify_greedy_rejection_emits_correction():
    logits = _mk_logits([4, 5, 6])
    tokens = jnp.asarray([[9, 4, 7]], jnp.int32)      # d2 != argmax 5
    n_acc, out = verify_draft(logits, tokens, jnp.asarray([3]),
                              jax.random.PRNGKey(0), 0.7, 0.9, greedy=True)
    assert int(n_acc[0]) == 1 and int(out[0]) == 5    # corrected token


def test_verify_respects_nvalid_mask():
    logits = _mk_logits([4, 5, 6])
    # row feeds only [last] (no drafts): padding draft columns must not count
    tokens = jnp.asarray([[9, 4, 5]], jnp.int32)
    n_acc, out = verify_draft(logits, tokens, jnp.asarray([1]),
                              jax.random.PRNGKey(0), 0.7, 0.9, greedy=True)
    assert int(n_acc[0]) == 0 and int(out[0]) == 4


def test_verify_rejection_sampling_matches_target_distribution():
    """Committing [draft if accepted else residual sample] must reproduce the
    engine's sampling distribution exactly (Leviathan et al., deterministic
    proposal): empirical marginal of the first committed token over many keys
    == temperature/top-p target probs."""
    rng = np.random.default_rng(1)
    V, temp, top_p = 12, 0.9, 0.8
    logits = jnp.asarray(rng.standard_normal((1, 2, V)) * 2.0, jnp.float32)
    p_target = np.asarray(target_probs(logits[:, 0], temp, top_p))[0]

    for draft_tok in (int(np.argsort(p_target)[-2]),   # in-nucleus token
                      int(np.argmin(p_target))):       # usually zero-mass
        tokens = jnp.asarray([[3, draft_tok]], jnp.int32)
        nvalid = jnp.asarray([2])

        def one(key):
            n_acc, out = verify_draft(logits, tokens, nvalid, key,
                                      temp, top_p, greedy=False)
            return jnp.where(n_acc[0] >= 1, tokens[0, 1], out[0])

        n = 4000
        toks = np.asarray(jax.vmap(one)(jax.random.split(jax.random.PRNGKey(0), n)))
        emp = np.bincount(toks, minlength=V) / n
        assert np.abs(emp - p_target).max() < 0.035, (emp, p_target)


# ------------------------------------------------------------ engine paths
def test_engine_greedy_bit_identical(setup):
    cfg, model, params = setup
    prompts = _repetitive_prompts(cfg.vocab, 6)
    base_eng, base = _gen(model, params, prompts, spec=False)
    spec_eng, spec = _gen(model, params, prompts, spec=True)
    assert base == spec
    assert spec_eng.drafted_tokens > 0 and spec_eng.accepted_tokens > 0
    assert spec_eng.stats()["spec_acceptance_rate"] > 0
    spec_eng.allocator.check_invariants()
    assert not spec_eng.allocator._ref, "pages leaked after all requests done"


def test_engine_greedy_identical_under_preemption(setup):
    """Tight page pool forces preempt/pause-resume; speculative growth and
    rollback must preserve bit-identical output through it."""
    cfg, model, params = setup
    prompts = _repetitive_prompts(cfg.vocab, 6, seed=5)
    kw = dict(num_pages=24, max_slots=4, token_budget=24)
    base_eng, base = _gen(model, params, prompts, spec=False, **kw)
    spec_eng, spec = _gen(model, params, prompts, spec=True, **kw)
    assert base == spec
    assert spec_eng.drafted_tokens > 0
    spec_eng.allocator.check_invariants()
    assert not spec_eng.allocator._ref


def test_engine_greedy_identical_with_prefix_cache(setup):
    """Speculative decode + shared-prefix COW: warm trie hits, drafting and
    rollback compose; outputs stay bit-identical and rolled-back pages are
    never left registered or referenced."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    shared = rng.integers(1, cfg.vocab, 16).astype(np.int32)   # 4 full pages
    prompts = [np.concatenate([shared, rng.integers(1, cfg.vocab, 6).astype(np.int32)])
               for _ in range(5)]
    outs = {}
    engines = {}
    for spec in (False, True):
        eng = InferenceEngine(model, params, EngineConfig(
            max_slots=4, page_size=4, num_pages=256, max_seq=128,
            prefill_bucket=8, greedy=True, enable_prefix_cache=True,
            enable_speculative=spec, spec_k=4))
        # seed the trie, then run the batch warm
        eng.generate([Request(req_id=f"seed{spec}", prompt_tokens=prompts[0],
                              max_new_tokens=2)])
        reqs = [Request(req_id=f"warm{spec}-{i}", prompt_tokens=p,
                        max_new_tokens=20) for i, p in enumerate(prompts)]
        eng.generate(reqs)
        outs[spec] = [r.generated for r in reqs]
        engines[spec] = eng
    assert outs[False] == outs[True]
    spec_eng = engines[True]
    assert spec_eng.stats()["prefix_hit_rate"] > 0
    assert spec_eng.drafted_tokens > 0
    spec_eng.allocator.check_invariants()
    assert not spec_eng.allocator._ref


def test_engine_sampled_mode_runs_and_counts(setup):
    """Sampled requests take the rejection-sampling verify path; the engine
    must complete, count drafts, and leave no pages referenced."""
    cfg, model, params = setup
    prompts = _repetitive_prompts(cfg.vocab, 4)
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=4, page_size=4, num_pages=256, max_seq=128, prefill_bucket=8,
        greedy=False, temperature=0.7, top_p=0.9,
        enable_speculative=True, spec_k=4))
    reqs = [Request(req_id=f"s{i}", prompt_tokens=p, max_new_tokens=16)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    assert all(len(r.generated) == 16 for r in reqs)
    assert eng.drafted_tokens > 0
    eng.allocator.check_invariants()
    assert not eng.allocator._ref


def test_ssm_arch_gates_speculation_off():
    """Rollback is a pure KV-length decrement — unsound for SSM recurrent
    state, so hybrid/SSM models silently disable speculation."""
    cfg = tiny_config("mamba2-1.3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=4, num_pages=64, max_seq=64, prefill_bucket=8,
        greedy=True, enable_speculative=True, spec_k=4))
    assert not eng.spec_on
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=f"m{i}", prompt_tokens=np.tile(rng.integers(1, cfg.vocab, 4), 4).astype(np.int32),
                    max_new_tokens=8) for i in range(2)]
    eng.generate(reqs)
    assert all(len(r.generated) == 8 for r in reqs)
    assert eng.drafted_tokens == 0 and eng.spec_steps == 0


def test_stats_surface_spec_counters(setup):
    cfg, model, params = setup
    prompts = _repetitive_prompts(cfg.vocab, 4)
    eng, _ = _gen(model, params, prompts, spec=True)
    s = eng.stats()
    for key in ("spec_steps", "drafted_tokens", "accepted_tokens",
                "spec_acceptance_rate"):
        assert key in s
    assert s["spec_steps"] > 0
    assert 0 < s["spec_acceptance_rate"] <= 1
    assert s["accepted_tokens"] <= s["drafted_tokens"]
