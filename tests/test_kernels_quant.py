"""w8a16 quantized matmul kernel vs oracle + end-to-end quantization error."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quant_matmul import quantize_int8, w8a16_matmul, w8a16_matmul_reference
from repro.quant import dequantize_tree, quantize_params_int8
from repro.quant.quantize import kv_dequantize, kv_quantize


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (16, 64, 32, 8, 16, 32), (32, 128, 64, 16, 32, 64), (8, 32, 16, 8, 16, 16),
])
def test_w8a16_kernel(rng, M, K, N, bm, bn, bk):
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    wq, sc = quantize_int8(w)
    ref = w8a16_matmul_reference(x, wq, sc)
    out = w8a16_matmul(x, wq, sc, backend="pallas", interpret=True,
                       block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3, rtol=1e-3)


def test_quantization_error_bound(rng):
    w = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    wq, sc = quantize_int8(w)
    exact = x @ w
    quant = w8a16_matmul_reference(x, wq, sc)
    rel = float(jnp.max(jnp.abs(exact - quant)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.05, rel


def test_params_tree_quantization(rng):
    tree = {"big": jnp.asarray(rng.standard_normal((128, 256)), jnp.float32),
            "small": jnp.ones((8,), jnp.float32)}
    q = quantize_params_int8(tree)
    assert q["big"].q.dtype == jnp.int8
    assert q["small"].dtype == jnp.float32      # small leaves untouched
    back = dequantize_tree(q, jnp.float32)
    rel = float(jnp.max(jnp.abs(back["big"] - tree["big"])))
    assert rel < 0.05


def test_kv_quant_roundtrip(rng):
    kv = jnp.asarray(rng.standard_normal((3, 7, 2, 16)), jnp.float32)
    q, s = kv_quantize(kv)
    back = kv_dequantize(q, s)
    assert float(jnp.max(jnp.abs(back - kv))) < 0.05
