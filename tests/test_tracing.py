"""End-to-end request tracing + iteration profiling: span lists must
reconcile with the Figure-4 timestamps, the engine must leave one StepRecord
per iteration, and the open-loop arrival schedule must drive the client."""
import asyncio
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, MetricsSink,
                        Replica, ReplicaRouter, RouterConfig, Tracer,
                        scale_gateway_config)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.metrics import Request
from repro.data.workload import (WorkloadSpec, sample_arrivals,
                                 sample_workload)
from repro.models import build_model

TOL = 0.25                       # CPU-scheduling slack for timestamp checks


@pytest.fixture(scope="module")
def stack():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, tracer=None, **over):
    kw = dict(max_slots=3, page_size=8, num_pages=64, max_seq=64,
              prefill_bucket=16, greedy=True)
    kw.update(over)
    return InferenceEngine(model, params, EngineConfig(**kw), tracer=tracer)


def _reqs(cfg, n, length=12, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(req_id=f"x{i}",
                    prompt_tokens=rng.integers(1, cfg.vocab, length).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


# ----------------------------------------------------------- engine tracing
def test_engine_spans_cover_serving_path(stack):
    cfg, model, params = stack
    tracer = Tracer()
    eng = _engine(model, params, tracer=tracer)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs)
    for r in reqs:
        spans = tracer.pop(r.req_id)
        names = [s.name for s in spans]
        assert names[0] == "queue"
        assert "prefill_chunk" in names and "decode" in names
        # queue ends at engine admission (Figure-4 t2), within tolerance
        q = spans[0]
        assert abs(q.t1 - r.t2) < TOL
        # prefill chunks account for every uncached prompt token
        fed = sum(s.attrs["n_tokens"] for s in spans
                  if s.name == "prefill_chunk")
        assert fed + q.attrs["cached_tokens"] == len(r.prompt_tokens)
        # decode iterations coalesce: one span, one iter per generated token
        # after the first (the last prefill chunk emits token #1)
        dec = [s for s in spans if s.name == "decode"]
        assert sum(s.attrs["n_iters"] for s in dec) == r.n_generated - 1
        # every span sits inside the engine phase of the request's life
        for s in spans:
            assert s.t0 <= s.t1 + 1e-9
            assert r.t1 - TOL <= s.t0 and s.t1 <= r.t3 + TOL
    assert len(tracer) == 0


def test_engine_cancel_discards_trace(stack):
    cfg, model, params = stack
    tracer = Tracer()
    eng = _engine(model, params, tracer=tracer)
    (r,) = _reqs(cfg, 1, max_new=50)
    eng.submit(r)
    for _ in range(3):
        eng.step()
    assert tracer.peek(r.req_id)
    assert eng.cancel(r.req_id)
    assert tracer.peek(r.req_id) == []


def test_tracing_disabled_records_nothing(stack):
    cfg, model, params = stack
    tracer = Tracer(enabled=False)
    eng = _engine(model, params, tracer=tracer)
    reqs = _reqs(cfg, 2)
    eng.generate(reqs)
    assert all(r.finished for r in reqs)
    assert len(tracer) == 0


# --------------------------------------------------------- step profiling
def test_step_records_one_per_iteration(stack):
    cfg, model, params = stack
    eng = _engine(model, params)
    reqs = _reqs(cfg, 3, max_new=5)
    eng.generate(reqs)
    recs = list(eng.step_records)
    assert len(recs) == eng.steps
    assert [r.step for r in recs] == sorted(r.step for r in recs)
    for rec in recs:
        assert rec.t1 >= rec.t0
        assert 0 <= rec.tokens_packed <= rec.budget
        assert rec.occupancy <= rec.max_slots
        assert 0 <= rec.kv_free_pages <= rec.kv_total_pages
        assert rec.prefill_tokens + rec.decode_tokens <= rec.tokens_packed
    # prefill accounting: every prompt token fed exactly once
    assert (sum(r.prefill_tokens for r in recs)
            == sum(len(r.prompt_tokens) for r in reqs))
    # each request emits token #1 from prefill, the rest from decode
    assert (sum(r.decode_tokens for r in recs)
            == sum(r.n_generated - 1 for r in reqs))


def test_step_profile_disabled_and_ring_cap(stack):
    cfg, model, params = stack
    eng = _engine(model, params, profile_steps=False)
    reqs = _reqs(cfg, 2, max_new=4)
    eng.generate(reqs)
    assert list(eng.step_records) == [] and eng.steps > 0
    eng2 = _engine(model, params, step_records_cap=4)
    reqs2 = _reqs(cfg, 2, max_new=8, seed=1)
    eng2.generate(reqs2)
    recs = list(eng2.step_records)
    assert len(recs) == 4                           # bounded ring
    assert recs[-1].step == eng2.steps              # keeps the newest


# ------------------------------------------------------------ e2e export
def test_gateway_trace_export_figure4_consistency(stack, tmp_path):
    cfg, model, params = stack
    path = str(tmp_path / "traces.jsonl")
    tracer = Tracer()
    sink = MetricsSink(path)
    prompts = [np.random.default_rng(i).integers(1, cfg.vocab, 10 + 3 * i)
               .astype(np.int32) for i in range(5)]

    async def main():
        rep = Replica("t0", _engine(model, params, tracer=tracer,
                                    max_slots=4, num_pages=128,
                                    max_seq=128)).start()
        router = ReplicaRouter([rep], RouterConfig(policy="least_loaded"),
                               sink=sink, tracer=tracer)
        gw = Gateway(router, scale_gateway_config())
        res = await run_workload(gw, prompts, concurrency=3,
                                 max_new_tokens=6, timeout_s=120)
        merge_engine_timestamps(res.requests, gw)
        rep.stop()
        return res

    res = asyncio.run(main())
    assert all(r.finished for r in res.requests)
    sink.close()
    traces = {rec["req_id"]: rec
              for rec in map(json.loads, open(path)) if rec["kind"] == "trace"}
    assert len(traces) == len(prompts)
    assert len(tracer) == 0                        # popped on export
    for r in res.requests:
        rec = traces[r.req_id]
        spans = rec["spans"]
        names = [s["name"] for s in spans]
        for expected in ("gateway_admission", "route", "queue",
                         "prefill_chunk", "decode"):
            assert expected in names, (r.req_id, names)
        # Figure-4 reconciliation: the exported t0..t6 are the request's own,
        # and every span fits the [t1, t6] serving window
        assert rec["t1"] == pytest.approx(r.t1)
        assert rec["n_generated"] == r.n_generated
        for s in spans:
            assert r.t1 - TOL <= s["t0"] <= s["t1"] <= r.t6 + TOL
        q = next(s for s in spans if s["name"] == "queue")
        assert abs(q["t1"] - r.t2) < TOL
        fed = sum(s["attrs"]["n_tokens"] for s in spans
                  if s["name"] == "prefill_chunk")
        assert fed + q["attrs"]["cached_tokens"] == len(r.prompt_tokens)


# ------------------------------------------------------- open-loop arrivals
def test_sample_arrivals_schedule():
    spec = WorkloadSpec(n_requests=400, vocab=100, arrival_rate=50.0,
                        burst_mult=4.0, burst_period_s=1.0, burst_duty=0.25,
                        seed=3)
    arr = sample_arrivals(spec)
    assert len(arr) == 400
    assert arr == sorted(arr) and arr[0] > 0
    # mean rate sits between the base and burst rates
    mean_rate = len(arr) / arr[-1]
    assert 50.0 < mean_rate < 200.0
    # the schedule stream is decoupled from prompt sampling
    p1, o1 = sample_workload(spec)
    p2, o2 = sample_workload(dataclasses.replace(spec, arrival_rate=5.0))
    assert o1 == o2 and all((a == b).all() for a, b in zip(p1, p2))
    # closed loop: no schedule
    assert sample_arrivals(dataclasses.replace(spec, arrival_rate=0.0,
                                               n_requests=7)) == [0.0] * 7


def test_open_loop_client(stack):
    cfg, model, params = stack

    async def main():
        rep = Replica("o0", _engine(model, params, max_slots=4,
                                    num_pages=128, max_seq=128)).start()
        router = ReplicaRouter([rep], RouterConfig(policy="least_loaded"))
        gw = Gateway(router, scale_gateway_config())
        prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(4)]
        arrivals = [0.0, 0.05, 0.10, 0.40]
        res = await run_workload(gw, prompts, concurrency=1,  # ignored
                                 max_new_tokens=4, timeout_s=60,
                                 arrivals=arrivals)
        rep.stop()
        return res

    res = asyncio.run(main())
    assert all(r.finished for r in res.requests)
    by_id = {r.req_id: r for r in res.requests}
    # each request was submitted no earlier than its scheduled arrival
    t_base = min(r.t0 for r in res.requests)
    for i, off in enumerate([0.0, 0.05, 0.10, 0.40]):
        assert by_id[f"req-{i}"].t0 >= t_base + off - 0.02
