"""Chunked paged prefill (DESIGN.md §2): kernel oracle equivalence, chunked
== dense prefill logits across chunk sizes (incl. ragged prompts),
preempt-then-resume determinism, and the per-iteration token budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.metrics import Request
from repro.kernels.paged_attention import (chunked_prefill_attention,
                                           chunked_prefill_reference)
from repro.models import RunCtx, build_model

CTX = RunCtx(attn_backend="xla", moe_strategy="dropless", block_q=128, block_kv=128)

# rtol/atol for chunked-vs-dense logits: both paths compute attention and
# softmax in f32; the differences are reduction-order only.
LOGIT_ATOL = 2e-3


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------- kernel
def _rand_pool(rng, B, maxp, ps, Hkv, D):
    P = B * maxp + 1
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    pt = jnp.asarray([[1 + b * maxp + i for i in range(maxp)] for b in range(B)],
                     jnp.int32)
    return kp, vp, pt


@pytest.mark.parametrize("window", [0, 5])
def test_chunked_kernel_matches_bruteforce(window):
    rng = np.random.default_rng(0)
    B, C, H, Hkv, D, ps, maxp = 3, 8, 4, 2, 16, 4, 8
    kp, vp, pt = _rand_pool(rng, B, maxp, ps, Hkv, D)
    starts = jnp.asarray([5, 0, 13], jnp.int32)
    nvalid = np.array([8, 6, 3])
    lengths = jnp.asarray(np.asarray(starts) + nvalid, jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, C, H, D)), jnp.float32)
    qpos = starts[:, None] + jnp.arange(C)[None]

    kg = np.asarray(kp)[np.asarray(pt)].reshape(B, maxp * ps, Hkv, D)
    vg = np.asarray(vp)[np.asarray(pt)].reshape(B, maxp * ps, Hkv, D)
    oracle = np.zeros((B, C, H, D), np.float32)
    for b in range(B):
        for i in range(C):
            p_abs = int(starts[b]) + i
            for h in range(H):
                hk = h // (H // Hkv)
                s = (kg[b, :, hk] @ np.asarray(q)[b, i, h]) * (D ** -0.5)
                kv = np.arange(maxp * ps)
                m = (kv < int(lengths[b])) & (kv <= p_abs)
                if window > 0:
                    m &= kv > p_abs - window
                s = np.where(m, s, -1e30)
                w = np.exp(s - s.max())
                w = np.where(m, w, 0.0)
                if w.sum() > 0:
                    w /= w.sum()
                oracle[b, i, h] = w @ vg[b, :, hk]

    ref = chunked_prefill_reference(q, kp, vp, pt, lengths, qpos,
                                    scale=D ** -0.5, window=window)
    pal = chunked_prefill_attention(q, kp, vp, pt, lengths, qpos,
                                    scale=D ** -0.5, window=window,
                                    backend="pallas", interpret=True)
    for b in range(B):
        n = nvalid[b]
        assert np.abs(np.asarray(ref)[b, :n] - oracle[b, :n]).max() < 1e-5
        assert np.abs(np.asarray(pal)[b, :n] - oracle[b, :n]).max() < 1e-5


# ---------------------------------------------------------------- model
@pytest.mark.parametrize("chunk", [3, 5, 13, 16])
def test_chunked_prefill_matches_dense_logits(setup, chunk):
    """Prompt length 13 is not divisible by chunks 3/5/16; chunk 13 is the
    whole-prompt case. All must match the dense-prefill reference."""
    cfg, model, params = setup
    S, gen, ps, maxp = 13, 4, 4, 16
    r = np.random.default_rng(2)
    toks = r.integers(0, cfg.vocab, S + gen).astype(np.int32)

    dense = model.init_cache(1, 64, jnp.float32, kind="dense")
    lg, dcache = model.prefill(params, {"tokens": jnp.asarray(toks[:S])[None]},
                               dense, CTX)
    ref = [np.asarray(lg[0])]
    for i in range(gen):
        lg, dcache = model.decode_step(params, jnp.asarray(toks[S + i:S + i + 1])[None],
                                       dcache, jnp.asarray([S + i], jnp.int32), CTX)
        ref.append(np.asarray(lg[0]))

    paged = model.init_cache(2, 64, jnp.float32, kind="paged",
                             page_size=ps, num_pages=64)
    pt = jnp.asarray(np.arange(1, maxp + 1, dtype=np.int32)[None])
    slot = jnp.asarray([1], jnp.int32)
    out = []
    fed, firstc = 0, True
    while fed < S:
        n = min(chunk, S - fed)
        tk = np.zeros((1, chunk), np.int32)
        tk[0, :n] = toks[fed:fed + n]
        lg, paged = model.decode_chunk(
            params, jnp.asarray(tk), paged, jnp.asarray([fed], jnp.int32),
            jnp.asarray([n], jnp.int32), slot, jnp.asarray([firstc]), CTX, pt)
        fed += n
        firstc = False
    out.append(np.asarray(lg[0]))
    for i in range(gen):
        lg, paged = model.decode_chunk(
            params, jnp.asarray(toks[S + i:S + i + 1])[None], paged,
            jnp.asarray([S + i], jnp.int32), jnp.asarray([1], jnp.int32),
            slot, jnp.asarray([False]), CTX, pt)
        out.append(np.asarray(lg[0]))
    errs = [float(np.abs(a - b).max()) for a, b in zip(ref, out)]
    assert max(errs) < LOGIT_ATOL, errs


def _ref_greedy(model, params, prompt, n):
    cache = model.init_cache(1, 128, jnp.float32, kind="dense")
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, cache, CTX)
    out = [int(jnp.argmax(lg[0]))]
    for i in range(n - 1):
        lg, cache = model.decode_step(params, jnp.asarray([[out[-1]]]), cache,
                                      jnp.asarray([len(prompt) + i], jnp.int32), CTX)
        out.append(int(jnp.argmax(lg[0])))
    return out


# ---------------------------------------------------------------- engine
def test_engine_multi_chunk_prefill_matches_reference(setup):
    """Chunk smaller than the prompt: prefill spans several iterations while
    other slots decode, and greedy output still matches the pure model."""
    cfg, model, params = setup
    r = np.random.default_rng(3)
    prompts = [r.integers(1, cfg.vocab, int(n)).astype(np.int32)
               for n in [19, 7, 26, 11]]
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=3, page_size=8, num_pages=64, max_seq=64,
        prefill_chunk=8, token_budget=12, greedy=True))
    reqs = [Request(req_id=f"c{i}", prompt_tokens=p, max_new_tokens=10)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    eng.allocator.check_invariants()
    assert max(eng.iter_token_counts) <= 12
    for req, p in zip(reqs, prompts):
        assert req.finished
        assert req.generated == _ref_greedy(model, params, p, 10)


def test_engine_preempt_resume_reproduces_tokens(setup):
    """Few pages force mid-stream preemption of partially-decoded requests;
    resumed slots (re-prefilled in chunks) must emit identical tokens."""
    cfg, model, params = setup
    r = np.random.default_rng(4)
    prompts = [r.integers(1, cfg.vocab, 12).astype(np.int32) for _ in range(4)]
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=3, page_size=8, num_pages=8, max_seq=64,
        prefill_chunk=5, token_budget=9, greedy=True))
    reqs = [Request(req_id=f"p{i}", prompt_tokens=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    eng.allocator.check_invariants()
    assert eng.scheduler.n_preemptions > 0, "test must exercise preemption"
    for req, p in zip(reqs, prompts):
        assert req.finished
        assert req.generated == _ref_greedy(model, params, p, 12)


def test_iteration_token_budget_held_under_load(setup):
    """64 concurrent requests with mixed prompt lengths: no iteration may
    exceed the configured token budget."""
    cfg, model, params = setup
    budget = 24
    r = np.random.default_rng(5)
    prompts = [r.integers(1, cfg.vocab, int(r.integers(4, 40))).astype(np.int32)
               for _ in range(64)]
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=8, page_size=8, num_pages=256, max_seq=64,
        prefill_chunk=8, token_budget=budget, greedy=True))
    reqs = [Request(req_id=f"b{i}", prompt_tokens=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    assert all(q.finished for q in reqs)
    counts = list(eng.iter_token_counts)
    assert max(counts) <= budget, max(counts)
    # the pack must actually mix prefill chunks and decode tokens
    assert eng.prefill_tokens > 0 and eng.decode_tokens > 0


def test_oversized_prompt_fails_fast(setup):
    """A prompt that can never fit max_seq must finish immediately with zero
    tokens (the legacy dense-prefill engine crashed on these)."""
    cfg, model, params = setup
    r = np.random.default_rng(7)
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=64, max_seq=32,
        prefill_chunk=8, greedy=True))
    big = Request(req_id="big", prompt_tokens=r.integers(1, cfg.vocab, 50).astype(np.int32),
                  max_new_tokens=4)
    ok = Request(req_id="ok", prompt_tokens=r.integers(1, cfg.vocab, 6).astype(np.int32),
                 max_new_tokens=4)
    eng.generate([big, ok], max_steps=200)
    assert big.finished and len(big.generated) == 0
    assert ok.finished and len(ok.generated) == 4
    eng.allocator.check_invariants()


def test_no_dense_cache_on_serving_path(setup):
    """The serving engine must never allocate a dense per-request cache or
    run a scatter copy: the legacy hooks are gone and init_cache(dense) is
    not called during generate()."""
    cfg, model, params = setup
    for attr in ("_run_prefill", "_scatter_fn", "_prefill_fn", "_bucket"):
        assert not hasattr(InferenceEngine, attr)
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=32, max_seq=64,
        prefill_chunk=8, greedy=True))
    calls = []
    orig = model.init_cache
    model.init_cache = lambda *a, **k: (calls.append(k.get("kind", "dense")),
                                        orig(*a, **k))[1]
    try:
        r = np.random.default_rng(6)
        reqs = [Request(req_id="d0", prompt_tokens=r.integers(1, cfg.vocab, 9).astype(np.int32),
                        max_new_tokens=4)]
        eng.generate(reqs)
    finally:
        model.init_cache = orig
    assert reqs[0].finished
    assert "dense" not in calls
