"""Grouped expert matmul (megablox-style gmm) vs oracle, incl. hypothesis
sweep over ragged group sizes (empty groups, single-expert skew)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.kernels.moe_gmm import gmm, gmm_reference


def _run(rng, group_sizes, K=16, N=24, block_m=8, block_n=8):
    gs = np.asarray(group_sizes, np.int32)
    M, E = int(gs.sum()), len(gs)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
    ref = gmm_reference(x, w, jnp.asarray(gs))
    out = gmm(x, w, jnp.asarray(gs), backend="pallas", interpret=True,
              block_m=block_m, block_n=block_n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("sizes", [[8, 8, 8, 8], [0, 32, 0, 1], [33], [1, 1, 1, 1, 29]])
def test_gmm_fixed(rng, sizes):
    _run(rng, sizes)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=1, max_size=6).filter(lambda s: sum(s) > 0))
def test_gmm_hypothesis(sizes):
    _run(np.random.default_rng(sum(sizes)), sizes)


def test_gmm_bf16(rng):
    gs = jnp.asarray([5, 11], jnp.int32)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.bfloat16)
    ref = gmm_reference(x, w, gs)
    out = gmm(x, w, gs, backend="pallas", interpret=True, block_m=8, block_n=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2, rtol=5e-2)
