"""Fault injection + automatic failure detection + graceful degradation
(DESIGN.md §5): deterministic fault plans, the router's health monitor
turning injected crashes/stalls into automatic failover, the transient-submit
retry budget, the hedge-timer leak regression, orphan-drop terminal events,
load shedding, and the brown-out hysteresis controller.

Uses a jax-free FakeEngine so these run fast and deterministically; the
real-engine chaos path (pages freed under crash + cancel + failover) lives
in test_kv_cache.py.
"""
import asyncio
import threading
import time

import numpy as np

from repro.core import (FaultInjector, FaultPlan, Gateway, GatewayConfig,
                        PagedAllocator, Replica, ReplicaRouter, RouterConfig,
                        TransientSubmitError)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.engine import TokenEvent
from repro.core.metrics import Request, now


class FakeEngine:
    """Minimal engine contract for Replica: one token per active request per
    step, finishing at max_new_tokens. ``step_sleep`` makes generations take
    wall time so faults can land mid-stream."""

    def __init__(self, step_sleep: float = 0.0):
        self.step_sleep = step_sleep
        self.active = {}
        self.injector = None
        self.fault_key = None
        self.degraded = False
        self.step_records = []

    def submit(self, req):
        self.active[req.req_id] = req

    def cancel(self, rid):
        self.active.pop(rid, None)

    def has_work(self):
        return bool(self.active)

    def step(self):
        if self.injector is not None:
            self.injector.on_engine_step(self)
        if self.step_sleep:
            time.sleep(self.step_sleep)
        events = []
        for rid in list(self.active):
            req = self.active[rid]
            req.generated.append(len(req.generated) + 1)
            t = now()
            fin = len(req.generated) >= req.max_new_tokens
            if fin:
                req.finished = True
                req.t3 = t
                del self.active[rid]
            events.append(TokenEvent(req, req.generated[-1], t, fin))
        return events

    def stats(self):
        return {}


def _req(rid="r", max_new=4):
    return Request(req_id=rid, prompt_tokens=np.arange(1, 4, dtype=np.int32),
                   max_new_tokens=max_new)


def _wait(pred, timeout=10.0):
    deadline = time.monotonic() + timeout
    while not pred() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert pred(), "condition not reached before timeout"


# --------------------------------------------------------------- plan/injector
def test_coin_deterministic_across_injectors():
    a = FaultInjector(FaultPlan(seed=7))
    b = FaultInjector(FaultPlan(seed=7))
    assert a._coin("submit", "req-1", 0) == b._coin("submit", "req-1", 0)
    c = FaultInjector(FaultPlan(seed=8))
    assert a._coin("submit", "req-1", 0) != c._coin("submit", "req-1", 0)
    # independent of evaluation order
    x = a._coin("submit", "req-2", 3)
    assert a._coin("submit", "req-2", 3) == x


def test_plan_windows_and_single_shot_crash():
    t = {"v": 0.0}
    inj = FaultInjector(FaultPlan().stall("r", 1.0, 2.0).crash("r", 5.0),
                        clock=lambda: t["v"]).start()
    assert inj.replica_action("r") is None
    assert inj.replica_action("other") is None
    t["v"] = 1.5
    kind, remaining = inj.replica_action("r")
    assert kind == "stall" and abs(remaining - 1.5) < 1e-9
    t["v"] = 3.5                        # stall window closed
    assert inj.replica_action("r") is None
    t["v"] = 5.0
    assert inj.replica_action("r") == ("crash", 0.0)
    assert inj.replica_action("r") is None      # crash fires exactly once
    assert inj.injected["crash"] == 1


def test_kv_pressure_hold_and_release():
    t = {"v": 0.5}
    inj = FaultInjector(FaultPlan().kv_pressure("r", 0.0, 1.0, pages=5),
                        clock=lambda: t["v"]).start()
    eng = FakeEngine()
    eng.fault_key = "r"
    eng.allocator = PagedAllocator(num_pages=16, page_size=8,
                                   max_pages_per_seq=8)
    inj.on_engine_step(eng)
    assert eng.allocator.held_pages(FaultInjector.HOLD_KEY) == 5
    eng.allocator.check_invariants()
    t["v"] = 2.0                        # window closed: hold returned
    inj.on_engine_step(eng)
    assert eng.allocator.held_pages(FaultInjector.HOLD_KEY) == 0
    assert eng.allocator.live_pages == 0
    eng.allocator.check_invariants()


def test_submit_error_coin_respects_prob():
    inj = FaultInjector(FaultPlan(seed=3).submit_error(0.0, 100.0, prob=1.0),
                        clock=lambda: 1.0).start()
    try:
        inj.on_submit("r0", "req-1", 0)
        raise AssertionError("expected TransientSubmitError")
    except TransientSubmitError:
        pass
    inj2 = FaultInjector(FaultPlan(seed=3).submit_error(0.0, 100.0, prob=0.0),
                         clock=lambda: 1.0).start()
    inj2.on_submit("r0", "req-1", 0)    # prob 0: never fires


# --------------------------------------------------------------- auto failover
def test_crash_detected_and_failed_over_automatically():
    inj = FaultInjector(FaultPlan().crash("c0", 0.05)).start()
    r0 = Replica("c0", FakeEngine(step_sleep=0.01), injector=inj).start()
    r1 = Replica("c1", FakeEngine()).start()
    router = ReplicaRouter([r0, r1], RouterConfig(monitor_interval_s=0.01))
    router.start_monitor()
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    router.submit(_req("x", max_new=200), on_event, replica=r0)
    _wait(lambda: "req" in done)
    router.stop_monitor()
    r0.stop()
    r1.stop()
    assert r0.crashed and not r0.healthy
    assert done["req"].error is None
    assert len(done["req"].generated) == 200
    assert router.auto_failovers == 1 and router.manual_failovers == 0
    assert [e.reason for e in router.failover_events] == ["crash"]
    assert router.failover_events[0].latency_s >= 0.0


def test_stall_detected_by_watchdog_and_failed_over():
    inj = FaultInjector(FaultPlan().stall("s0", 0.0, 30.0)).start()
    r0 = Replica("s0", FakeEngine(), injector=inj, step_watchdog_s=0.05).start()
    r1 = Replica("s1", FakeEngine()).start()
    router = ReplicaRouter([r0, r1], RouterConfig(monitor_interval_s=0.01))
    router.start_monitor()
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    # lands in the stalled replica's inbox and is never drained — the
    # watchdog must treat undrained submissions as work
    router.submit(_req("y", max_new=5), on_event, replica=r0)
    _wait(lambda: "req" in done)
    router.stop_monitor()
    r0.stop()
    r1.stop()
    assert done["req"].error is None and len(done["req"].generated) == 5
    assert router.auto_failovers == 1 and router.manual_failovers == 0
    assert [e.reason for e in router.failover_events] == ["stall"]


def test_orphans_get_terminal_event_when_no_replica_left():
    r0 = Replica("o0", FakeEngine(step_sleep=0.05)).start()
    router = ReplicaRouter([r0])
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    router.submit(_req("z", max_new=1000), on_event, replica=r0)
    time.sleep(0.1)                     # in flight on the only replica
    n = router.handle_failure(r0)
    assert n == 0                       # nothing re-dispatched...
    assert "req" in done                # ...but the client saw a terminal
    assert done["req"].error == "no replica for failover"
    assert router.sink.snapshot().get("failover_dropped") == 1
    assert router.manual_failovers == 1


# --------------------------------------------------------------- retry budget
def test_transient_submit_errors_retried_to_success():
    inj = FaultInjector(FaultPlan().submit_error(0.0, 0.1, prob=1.0)).start()
    r0 = Replica("t0", FakeEngine()).start()
    router = ReplicaRouter(
        [r0], RouterConfig(retry_budget=10, retry_backoff_s=0.02),
        injector=inj)
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    req = _req("t", max_new=3)
    router.submit(req, on_event)        # backoff outlasts the 0.1 s window
    _wait(lambda: "req" in done)
    r0.stop()
    assert done["req"].error is None and len(done["req"].generated) == 3
    assert req.retries >= 1
    assert router.sink.snapshot().get("retries", 0) >= 1
    assert inj.injected["submit_error"] >= 1


def test_retry_budget_exhaustion_is_terminal_not_a_hang():
    inj = FaultInjector(FaultPlan().submit_error(0.0, 300.0, prob=1.0)).start()
    r0 = Replica("e0", FakeEngine()).start()
    router = ReplicaRouter(
        [r0], RouterConfig(retry_budget=2, retry_backoff_s=0.001),
        injector=inj)
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    router.submit(_req("e", max_new=3), on_event)
    r0.stop()
    assert "req" in done
    assert done["req"].error.startswith("submit failed after")
    assert router.sink.snapshot().get("retry_exhausted") == 1
    assert router._req_state == {}      # accounting closed out


# --------------------------------------------------------------- hedge timer
def test_hedge_timer_cancelled_when_request_finishes_first():
    # regression: a request finishing before hedge_after_s used to leave a
    # live threading.Timer (and its _req_state) behind for every request
    timers_before = sum(isinstance(t, threading.Timer)
                        for t in threading.enumerate())
    r0 = Replica("h0", FakeEngine()).start()
    r1 = Replica("h1", FakeEngine()).start()
    router = ReplicaRouter([r0, r1],
                           RouterConfig(hedge_after_s=30.0))
    done = {}

    def on_event(ev):
        if ev.finished:
            done["req"] = ev.request

    router.submit(_req("h", max_new=2), on_event)
    _wait(lambda: "req" in done)
    _wait(lambda: router._req_state == {})
    _wait(lambda: sum(isinstance(t, threading.Timer)
                      for t in threading.enumerate()) <= timers_before)
    r0.stop()
    r1.stop()
    assert router.sink.snapshot().get("hedges", 0) == 0


# --------------------------------------------------------------- degradation
def test_gateway_sheds_over_admission_bound():
    async def main():
        r0 = Replica("g0", FakeEngine(step_sleep=0.02)).start()
        router = ReplicaRouter([r0])
        gw = Gateway(router, GatewayConfig(max_inflight=1))
        prompts = [np.arange(1, 6, dtype=np.int32)] * 4
        res = await run_workload(gw, prompts, concurrency=4,
                                 max_new_tokens=30, timeout_s=30.0,
                                 arrivals=[0.0, 0.02, 0.04, 0.06])
        merge_engine_timestamps(res.requests, gw)
        r0.stop()
        return res, gw

    res, gw = asyncio.run(main())
    shed = [r for r in res.requests if r.error == "shed"]
    ok = [r for r in res.requests if r.error is None and r.finished]
    assert len(shed) >= 1               # overflow answered immediately...
    assert len(ok) >= 1                 # ...while admitted work completes
    assert gw.inflight_max <= 1
    assert gw.sink.snapshot().get("shed", 0) == len(shed)


def test_brownout_hysteresis_and_degraded_broadcast():
    eng = FakeEngine()
    r0 = Replica("b0", eng)             # thread never started: state-only
    router = ReplicaRouter([r0])
    gw = Gateway(router, GatewayConfig(brownout_high=2, brownout_low=1,
                                       brownout_sustain_s=1.0,
                                       brownout_recover_s=2.0))
    gw._inflight = 3
    gw._update_brownout(100.0)          # overload observed...
    gw._update_brownout(100.5)          # ...but not yet sustained
    assert not gw.brownout
    gw._update_brownout(101.1)          # sustained past brownout_sustain_s
    assert gw.brownout and eng.degraded
    assert gw.brownout_activations == 1
    gw._inflight = 0
    gw._update_brownout(101.2)          # calm observed...
    gw._update_brownout(102.0)          # ...but not yet sustained
    assert gw.brownout
    gw._update_brownout(103.3)          # sustained past brownout_recover_s
    assert not gw.brownout and not eng.degraded
    s = gw.sink.snapshot()
    assert s.get("brownout_on") == 1 and s.get("brownout_off") == 1


def test_brownout_blip_below_sustain_never_arms():
    r0 = Replica("b1", FakeEngine())
    gw = Gateway(ReplicaRouter([r0]),
                 GatewayConfig(brownout_high=2, brownout_low=1,
                               brownout_sustain_s=1.0))
    gw._inflight = 5
    gw._update_brownout(10.0)
    gw._inflight = 0                    # blip over the watermark, then calm
    gw._update_brownout(10.5)
    gw._inflight = 5
    gw._update_brownout(11.2)           # over again, but the clock restarted
    assert not gw.brownout and gw.brownout_activations == 0
