"""Shared-prefix KV cache (DESIGN.md §2): trie semantics, warm requests skip
prefill and reproduce no-sharing outputs, COW on page-aligned prompts,
preempt/resume/cancel with shared pages leak nothing, SSM/encdec gating."""
import jax
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.kv_cache import PagedAllocator, PrefixCache
from repro.core.metrics import Request
from repro.models import build_model

PS = 8  # page size used throughout


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, cache=True, pages=64, slots=4, chunk=PS,
            budget=0, max_seq=64):
    return InferenceEngine(model, params, EngineConfig(
        max_slots=slots, page_size=PS, num_pages=pages, max_seq=max_seq,
        prefill_chunk=chunk, token_budget=budget, greedy=True,
        enable_prefix_cache=cache))


def _reqs(prompts, max_new=6, tag=""):
    return [Request(req_id=f"{tag}{i}", prompt_tokens=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _reference(model, params, prompts, max_new=6):
    """No-sharing engine output for the same prompts (chunked==dense is
    already pinned by tests/test_chunked_prefill.py)."""
    eng = _engine(model, params, cache=False)
    reqs = _reqs(prompts, max_new, tag="ref")
    eng.generate(reqs)
    return [r.generated for r in reqs]


# ---------------------------------------------------------------- trie
def test_trie_lookup_insert_evict():
    a = PagedAllocator(num_pages=32, page_size=4, max_pages_per_seq=8)
    trie = PrefixCache(a)
    toks = list(range(11))                 # 2 full blocks + ragged tail
    pages = a.allocate(0, len(toks))
    assert trie.lookup(toks) == []         # cold
    trie.insert(toks, pages, 2)
    assert len(trie) == 2
    assert trie.lookup(toks) == pages[:2]  # only full blocks match
    assert trie.lookup(toks[:8]) == pages[:2]
    assert trie.lookup(toks[:7]) == pages[:1]
    # divergent second block: first still hits
    div = toks[:4] + [99, 99, 99, 99]
    assert trie.lookup(div) == pages[:1]
    # free: registered full pages retire, the ragged tail page frees outright
    a.free(0)
    assert a.retired_pages == 2
    for s in range(1, 5):                  # take everything (8 pages per slot)
        a.allocate(s, 4 * 8 if s < 4 else 4 * 7)
    assert len(trie) == 0 and trie.lookup(toks) == []
    a.check_invariants()


# ---------------------------------------------------------------- engine
def test_warm_requests_skip_prefill_and_match(setup):
    cfg, model, params = setup
    r = np.random.default_rng(11)
    prefix = r.integers(1, cfg.vocab, 2 * PS).astype(np.int32)   # 2 full pages
    prompts = [np.concatenate([prefix, r.integers(1, cfg.vocab, int(t)).astype(np.int32)])
               for t in [5, 9, 3, 7]]
    eng = _engine(model, params)
    warm_up = _reqs([prompts[0]], tag="w")
    eng.generate(warm_up)
    fed_cold = eng.prefill_tokens
    reqs = _reqs(prompts, tag="q")
    eng.generate(reqs)
    eng.allocator.check_invariants()
    st = eng.stats()
    assert st["prefix_hit_pages"] >= 2 * len(prompts)
    # each warm request skipped the whole 2-page shared prefix
    assert eng.prefix_cached_tokens == 2 * PS * len(prompts)
    assert eng.prefill_tokens - fed_cold == sum(len(p) - 2 * PS for p in prompts)
    assert [q.generated for q in reqs] == _reference(model, params, prompts)


def test_page_aligned_prompt_triggers_cow_and_matches(setup):
    """Prompt length an exact multiple of the page size: every prompt token
    is cached, so the hit is capped at feed_len-1 and the re-fed last token
    must copy-on-write the final shared page, never mutating it in place."""
    cfg, model, params = setup
    r = np.random.default_rng(12)
    prompt = r.integers(1, cfg.vocab, 2 * PS).astype(np.int32)
    eng = _engine(model, params)
    eng.generate(_reqs([prompt], tag="cold"))
    assert eng.allocator.cow_copies == 0
    reqs = _reqs([prompt], tag="warm")
    eng.generate(reqs)
    eng.allocator.check_invariants()
    assert eng.allocator.cow_copies >= 1
    assert eng.prefix_cached_tokens == 2 * PS - 1
    assert [q.generated for q in reqs] == _reference(model, params, [prompt])


def test_preempt_resume_with_shared_pages_no_leak(setup):
    """Page pressure forces preemption of requests holding shared pages; on
    resume they re-hit the trie. Outputs must match the no-sharing engine and
    every reference must be released at the end."""
    cfg, model, params = setup
    r = np.random.default_rng(13)
    prefix = r.integers(1, cfg.vocab, PS).astype(np.int32)
    prompts = [np.concatenate([prefix, r.integers(1, cfg.vocab, 10).astype(np.int32)])
               for _ in range(5)]
    eng = _engine(model, params, pages=8, slots=3, chunk=5, budget=9)
    reqs = _reqs(prompts, max_new=10, tag="pr")
    eng.generate(reqs)
    eng.allocator.check_invariants()
    assert eng.scheduler.n_preemptions > 0, "test must exercise preemption"
    assert all(q.finished for q in reqs)
    assert not eng.allocator._ref, "page references leaked after finish"
    ref_eng = InferenceEngine(model, params, EngineConfig(
        max_slots=3, page_size=PS, num_pages=8, max_seq=64, prefill_chunk=5,
        token_budget=9, greedy=True, enable_prefix_cache=False))
    ref = _reqs(prompts, max_new=10, tag="prref")
    ref_eng.generate(ref)
    assert [q.generated for q in reqs] == [q.generated for q in ref]


def test_cancel_with_shared_pages_no_leak(setup):
    cfg, model, params = setup
    r = np.random.default_rng(14)
    prefix = r.integers(1, cfg.vocab, PS).astype(np.int32)
    prompts = [np.concatenate([prefix, r.integers(1, cfg.vocab, 4).astype(np.int32)])
               for _ in range(3)]
    eng = _engine(model, params)
    eng.generate(_reqs([prompts[0]], tag="seed"))     # populate the trie
    reqs = _reqs(prompts, max_new=16, tag="cx")
    for q in reqs:
        eng.submit(q)
    eng.step()                                        # all admitted, sharing
    assert eng.cancel("cx1")
    eng.generate([])                                  # drain the rest
    eng.allocator.check_invariants()
    assert not eng.allocator._ref
    assert all(q.finished for q in reqs if q.req_id != "cx1")


def test_eviction_under_pool_churn(setup):
    """More distinct prompts than the pool can cache: retired pages must be
    reclaimed (LRU) instead of raising OutOfPages, and outputs stay right."""
    cfg, model, params = setup
    r = np.random.default_rng(15)
    prompts = [r.integers(1, cfg.vocab, 2 * PS + 3).astype(np.int32)
               for _ in range(8)]
    eng = _engine(model, params, pages=13, slots=2)   # 12 usable pages
    reqs = _reqs(prompts, max_new=4, tag="ev")
    eng.generate(reqs)
    eng.allocator.check_invariants()
    assert all(q.finished for q in reqs)
    assert eng.allocator.evicted_pages > 0
    assert [q.generated for q in reqs] == _reference(model, params, prompts, max_new=4)


def test_prefix_cache_gated_off_for_ssm():
    cfg = tiny_config("mamba2-1.3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=16, max_seq=32, greedy=True))
    assert eng.prefix_cache is None
    assert eng.scheduler.prefix_cache is None
