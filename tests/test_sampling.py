"""sample_tokens unit tests: greedy/temperature-0 agreement, top-p
renormalization edge cases, determinism under a fixed key."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import sample_tokens


@pytest.fixture(scope="module")
def logits():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.standard_normal((5, 37)) * 3.0, jnp.float32)


def test_greedy_flag_matches_temperature_zero(logits):
    key = jax.random.PRNGKey(1)
    g = sample_tokens(logits, key, temperature=0.7, top_p=0.9, greedy=True)
    t0 = sample_tokens(logits, key, temperature=0.0, top_p=0.9, greedy=False)
    argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert (np.asarray(g) == np.asarray(argmax)).all()
    assert (np.asarray(t0) == np.asarray(argmax)).all()
    # negative temperature is the same deterministic path, not a crash
    tneg = sample_tokens(logits, key, temperature=-1.0, top_p=0.9, greedy=False)
    assert (np.asarray(tneg) == np.asarray(argmax)).all()


def test_top_p_one_keeps_full_distribution(logits):
    """p=1.0 must renormalize over the whole vocab: every token with nonzero
    probability stays reachable (checked by sampling many keys)."""
    seen = set()
    for s in range(200):
        out = sample_tokens(logits[:1], jax.random.PRNGKey(s),
                            temperature=5.0, top_p=1.0, greedy=False)
        seen.add(int(out[0]))
    # at high temperature over 37 near-uniform tokens, 200 draws cover many
    assert len(seen) > 10


def test_top_p_mass_on_one_token():
    """When one token holds ~all probability mass, any top_p (even tiny)
    keeps the head token — the first sorted token is always retained."""
    logits = jnp.zeros((3, 16)).at[:, 5].set(50.0)
    for p in (0.01, 0.5, 1.0):
        for s in range(20):
            out = sample_tokens(logits, jax.random.PRNGKey(s),
                                temperature=1.0, top_p=p, greedy=False)
            assert (np.asarray(out) == 5).all()


def test_top_p_truncates_tail():
    """Two dominant tokens cover > 0.9 of the mass; with top_p=0.5 only the
    head token survives truncation, so sampling is deterministic."""
    logits = jnp.zeros((1, 8)).at[0, 2].set(10.0).at[0, 6].set(9.0)
    outs = {int(sample_tokens(logits, jax.random.PRNGKey(s),
                              temperature=1.0, top_p=0.5, greedy=False)[0])
            for s in range(50)}
    assert outs == {2}
    # with top_p close to 1 both dominant tokens appear
    outs = {int(sample_tokens(logits, jax.random.PRNGKey(s),
                              temperature=1.0, top_p=0.999, greedy=False)[0])
            for s in range(50)}
    assert outs == {2, 6}


def test_fixed_key_is_deterministic(logits):
    key = jax.random.PRNGKey(42)
    a = sample_tokens(logits, key, temperature=0.8, top_p=0.9, greedy=False)
    b = sample_tokens(logits, key, temperature=0.8, top_p=0.9, greedy=False)
    assert (np.asarray(a) == np.asarray(b)).all()
    c = sample_tokens(logits, jax.random.PRNGKey(43), temperature=0.8,
                      top_p=0.9, greedy=False)
    assert (np.asarray(a) != np.asarray(c)).any()  # 5 rows, 37 tokens: differs
