"""Unit tests for the observability layer: Tracer/Span semantics, MetricsSink
durability + thread-safety, LogHistogram/TimelineAggregator math, and the TBT
unit contract (seconds per token, not ms)."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core.metrics import Request, request_metrics
from repro.core.observability import MetricsSink, Span, Tracer, spans_to_dicts
from repro.core.timeline import (LogHistogram, SLOConfig, StepRecord,
                                 TimelineAggregator)


# ------------------------------------------------------------------ tracer
def test_tracer_begin_end_and_attrs():
    tr = Tracer()
    tr.begin("r1", "queue", requeued=False)
    time.sleep(0.002)
    tr.end("r1", "queue", cached_tokens=16)
    (span,) = tr.peek("r1")
    assert span.name == "queue"
    assert span.duration > 0
    assert span.attrs == {"requeued": False, "cached_tokens": 16}


def test_tracer_end_without_begin_is_noop():
    tr = Tracer()
    tr.end("r1", "queue")
    assert tr.peek("r1") == []


def test_tracer_merge_coalesces_consecutive_spans():
    tr = Tracer()
    for i in range(5):
        tr.add("r1", "decode", float(i), float(i) + 0.5, merge=True,
               n_iters=1, tokens=1, last=(i == 4))
    spans = tr.pop("r1")
    assert len(spans) == 1
    s = spans[0]
    assert (s.t0, s.t1) == (0.0, 4.5)
    assert s.attrs["n_iters"] == 5 and s.attrs["tokens"] == 5
    assert s.attrs["last"] is True          # bools overwrite, never sum
    # a different name in between breaks the run
    tr.add("r2", "decode", 0.0, 1.0, merge=True, tokens=1)
    tr.add("r2", "preempt", 1.0, 1.0)
    tr.add("r2", "decode", 2.0, 3.0, merge=True, tokens=1)
    assert [s.name for s in tr.pop("r2")] == ["decode", "preempt", "decode"]


def test_tracer_disabled_is_falsy_noop():
    tr = Tracer(enabled=False)
    assert not tr
    tr.begin("r1", "queue")
    tr.end("r1", "queue")
    tr.add("r1", "x", 0.0, 1.0)
    tr.event("r1", "y")
    assert tr.pop("r1") == [] and len(tr) == 0


def test_tracer_bounds_spans_and_requests():
    tr = Tracer(max_spans=4, max_requests=2)
    for i in range(10):
        tr.add("r1", f"s{i}", 0.0, 1.0)
    assert len(tr.peek("r1")) == 4 and tr.dropped_spans == 6
    tr.add("r2", "a", 0.0, 1.0)
    tr.add("r3", "a", 0.0, 1.0)          # evicts r1 (oldest)
    assert len(tr) == 2 and tr.evicted_requests == 1
    assert tr.peek("r1") == [] and tr.peek("r3")


def test_tracer_pop_removes_open_spans():
    tr = Tracer()
    tr.begin("r1", "queue")
    tr.add("r1", "route", 0.0, 1.0)
    spans = tr.pop("r1")
    assert [s.name for s in spans] == ["route"]    # open span dropped
    tr.end("r1", "queue")                          # stale end: no-op
    assert tr.peek("r1") == []


def test_spans_to_dicts():
    d = spans_to_dicts([Span("x", 1.0, 2.0, {"k": 3})])
    assert d == [{"name": "x", "t0": 1.0, "t1": 2.0, "attrs": {"k": 3}}]
    json.dumps(d)                                   # JSONL-exportable


# -------------------------------------------------------------------- sink
def test_sink_concurrent_writers_no_torn_lines(tmp_path):
    path = str(tmp_path / "m.jsonl")
    sink = MetricsSink(path=path)
    n_threads, n_each = 8, 50
    stop = threading.Event()

    def writer(t):
        for i in range(n_each):
            sink.incr("ops")
            sink.record("probe", thread=t, i=i, payload="x" * 64)

    def flusher():
        while not stop.is_set():
            sink.flush()

    fl = threading.Thread(target=flusher)
    fl.start()
    threads = [threading.Thread(target=writer, args=(t,)) for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    stop.set()
    fl.join()
    sink.close()
    lines = open(path, "rb").read().splitlines()
    assert len(lines) == n_threads * n_each
    seen = set()
    for line in lines:
        rec = json.loads(line)                     # every line parses whole
        assert rec["kind"] == "probe"
        seen.add((rec["thread"], rec["i"]))
    assert len(seen) == n_threads * n_each          # none lost or duplicated
    assert sink.snapshot()["ops"] == n_threads * n_each


def test_sink_autoflush_and_idempotent_close(tmp_path):
    path = str(tmp_path / "auto.jsonl")
    sink = MetricsSink(path=path, flush_interval_s=0.02)
    sink.record("tick", i=1)
    deadline = time.time() + 2.0
    while time.time() < deadline:                  # reaches disk with no flush()
        try:
            if open(path).read().strip():
                break
        except FileNotFoundError:
            pass
        time.sleep(0.01)
    assert json.loads(open(path).read().splitlines()[0])["kind"] == "tick"
    sink.record("tock", i=2)
    assert sink.close() >= 0
    assert sink.close() == 0                       # idempotent
    assert not sink._flusher.is_alive()
    kinds = [json.loads(x)["kind"] for x in open(path).read().splitlines()]
    assert kinds == ["tick", "tock"]


def test_record_engine_gauge_semantics(tmp_path):
    sink = MetricsSink()
    sink.record_engine("e0", {"cow_copies": 3, "hit_rate": 0.5})
    sink.record_engine("e0", {"cow_copies": 7, "hit_rate": 0.25})
    snap = sink.snapshot()
    # cumulative engine counters are gauges: last value wins, never summed
    assert snap["engine.cow_copies"] == 7.0
    assert snap["engine.hit_rate"] == 0.25


def test_record_trace_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    sink = MetricsSink(path=path)
    r = Request(req_id="r1", prompt_tokens=np.arange(4, dtype=np.int32))
    r.t0, r.t4, r.t6 = 1.0, 1.5, 2.0
    r.generated = [1, 2, 3]
    sink.record_trace(r, [Span("queue", 1.1, 1.2, {"cached_tokens": 0})])
    sink.close()
    rec = json.loads(open(path).read())
    assert rec["kind"] == "trace" and rec["req_id"] == "r1"
    assert rec["n_generated"] == 3
    assert rec["spans"][0]["name"] == "queue"


# ------------------------------------------------------- tbt unit contract
def test_tbt_is_seconds_per_token():
    r = Request(req_id="r", prompt_tokens=np.arange(4, dtype=np.int32))
    r.t0, r.t4, r.t5 = 0.0, 0.5, 0.5
    r.t6 = 0.5 + 9 * 0.020                         # 10 tokens, 20ms apart
    r.generated = list(range(10))
    r.finished = True
    m = request_metrics(r)
    # TBT = (t6 - t5) / (Ng - 1) in SECONDS per token (docstring contract):
    # 20ms gaps must read as 0.02, not 20.
    assert m.tbt == pytest.approx(0.020)
    assert m.ttft == pytest.approx(0.5)


# --------------------------------------------------------------- histogram
def test_log_histogram_percentiles():
    h = LogHistogram()
    vals = [0.001 * (i + 1) for i in range(1000)]   # 1ms .. 1s uniform
    for v in vals:
        h.record(v)
    assert h.percentile(0) == pytest.approx(0.001)
    assert h.percentile(100) == pytest.approx(1.0)
    for p in (50, 90, 99):
        exact = vals[int(p / 100 * len(vals)) - 1]
        assert h.percentile(p) == pytest.approx(exact, rel=0.15)
    assert h.mean() == pytest.approx(sum(vals) / len(vals))


def test_log_histogram_underflow_and_merge():
    h = LogHistogram()
    h.record(0.0)                # below min_value: underflow bucket, but the
    assert h.percentile(50) == 0.0   # clamp to tracked min/max makes it exact
    other = LogHistogram()
    other.record(1.0)
    h.merge(other)
    assert h.count == 2 and h.percentile(100) == 1.0


# --------------------------------------------------------------- aggregator
def _step(step, t0, t1, **kw):
    base = dict(step=step, t0=t0, t1=t1, budget=64, tokens_packed=32,
                n_admitted=0, prefill_rows=0, prefill_tokens=0, decode_rows=8,
                decode_tokens=32, drafted_tokens=0, accepted_tokens=0,
                occupancy=8, max_slots=8, queue_depth=2, kv_free_pages=50,
                kv_total_pages=100, preemptions=0, cow_pages=0)
    base.update(kw)
    return StepRecord(**base)


def _req(req_id, t0, ttft_s, n_tokens, tbt_s):
    r = Request(req_id=req_id, prompt_tokens=np.arange(4, dtype=np.int32))
    r.t0, r.t1, r.t2 = t0, t0 + 0.001, t0 + 0.011
    r.t4 = r.t5 = t0 + ttft_s
    r.t6 = r.t5 + (n_tokens - 1) * tbt_s
    r.t3 = r.t6
    r.generated = list(range(n_tokens))
    r.finished = True
    return r


def test_timeline_windows_and_slo():
    agg = TimelineAggregator(window_s=1.0,
                             slo=SLOConfig(ttft_target_s=0.5, tbt_target_s=0.05))
    agg.add_steps([_step(0, 100.0, 100.1), _step(1, 100.5, 100.6),
                   _step(2, 101.2, 101.3, queue_depth=5, preemptions=1)])
    agg.add_request(_req("ok", 100.0, ttft_s=0.1, n_tokens=11, tbt_s=0.01))
    agg.add_request(_req("slow-ttft", 100.0, ttft_s=0.9, n_tokens=11,
                         tbt_s=0.01))
    agg.add_request(_req("slow-tbt", 101.0, ttft_s=0.1, n_tokens=11,
                         tbt_s=0.2))
    tl = agg.timeline()
    # origin = 100.1 (first ingested timestamp). Steps land in windows 0/0/1;
    # completions at t6 = 100.2, 101.0 (window 0) and 103.1 (window 3).
    assert [w["t"] for w in tl] == [0.0, 1.0, 3.0]
    w0 = tl[0]
    assert w0["steps"] == 2 and w0["throughput_tok_s"] == pytest.approx(64.0)
    assert w0["queue_depth_max"] == 2
    assert w0["kv_util_mean"] == pytest.approx(0.5)
    assert w0["occupancy_frac"] == pytest.approx(1.0)
    assert w0["budget_util"] == pytest.approx(0.5)
    # both completions land in window 0 (t6 ≈ 100.2 / 101.0): one attains
    assert w0["completed"] == 2 and w0["slo_attainment"] == pytest.approx(0.5)
    assert w0["p50_queue_wait_s"] == pytest.approx(0.01, rel=0.2)
    w1 = tl[1]
    assert w1["preemptions_per_s"] == pytest.approx(1.0)
    assert w1["queue_depth_max"] == 5
    w3 = tl[2]
    assert w3["completed"] == 1 and w3["slo_attainment"] == 0.0
    assert w3["ttft_ok_frac"] == 1.0 and w3["tbt_ok_frac"] == 0.0
    s = agg.summary()
    assert s["n_requests"] == 3 and s["n_steps"] == 3
    assert s["slo_attainment"] == pytest.approx(1 / 3)
    assert s["p50_ttft_s"] == pytest.approx(0.1, rel=0.2)


def test_timeline_empty_summary():
    agg = TimelineAggregator()
    assert agg.timeline() == []
    s = agg.summary()
    assert s["n_requests"] == 0 and s["slo_attainment"] is None
    assert s["throughput_tok_s"] == 0.0
