"""Continuous-batching scheduler: admission policies, pause/resume
(max-utilization), static batching, slot hygiene."""
import numpy as np

from repro.core.kv_cache import PagedAllocator
from repro.core.metrics import Request
from repro.core.scheduler import ContinuousBatchScheduler


def _req(i, n=8, max_new=4):
    return Request(req_id=f"r{i}", prompt_tokens=np.arange(1, n + 1, dtype=np.int32),
                   max_new_tokens=max_new)


def _sched(policy="max_utilization", pages=16, slots=2):
    alloc = PagedAllocator(num_pages=pages, page_size=4, max_pages_per_seq=16)
    return ContinuousBatchScheduler(slots, alloc, policy=policy), alloc


def test_admission_respects_slots():
    s, _ = _sched(slots=2)
    for i in range(4):
        s.add(_req(i))
    d = s.schedule()
    assert len(d.admit) == 2
    assert len(s.waiting) == 2
    assert set(st.slot for st in d.admit) == {0, 1}


def test_admission_respects_pages():
    s, a = _sched(pages=5, slots=4)      # 4 usable pages
    s.add(_req(0, n=8))                   # needs 3 (prompt+1)
    s.add(_req(1, n=8))
    d = s.schedule()
    assert len(d.admit) == 1              # second would overflow pending pages


def test_conservative_reserves_full_output():
    s, _ = _sched(policy="conservative", pages=9, slots=4)
    s.add(_req(0, n=8, max_new=24))       # needs (8+24)/4 = 8 pages
    s.add(_req(1, n=8, max_new=24))
    assert len(s.schedule().admit) == 1


def test_static_waits_for_batch():
    s, a = _sched(policy="static", pages=32, slots=2)
    for i in range(3):
        s.add(_req(i))
    d = s.schedule()
    assert len(d.admit) == 2
    for st in d.admit:
        a.allocate(st.slot, 8)
        st.fed = 8
    assert s.schedule().admit == []       # no refill mid-batch
    s.finish(d.admit[0].slot)
    assert s.schedule().admit == []       # still one running
    s.finish(d.admit[1].slot)
    assert len(s.schedule().admit) == 1   # fresh batch


def test_preemption_pauses_latest_and_requeues():
    s, a = _sched(pages=7, slots=3)       # 6 usable
    for i in range(2):
        s.add(_req(i, n=8))               # 2 pages each
    d = s.schedule()
    for st in d.admit:
        a.allocate(st.slot, 8)
        st.fed = 8
    # burn remaining pages so growth must preempt
    a.allocate(99, 8)
    victim_order = max(st.order for st in s.running.values())
    first = min(s.running.values(), key=lambda st: st.order)
    ok = s.grow_for_decode(first.slot)
    assert ok
    assert len(s.running) == 1
    assert s.waiting[0].preemptions == 1
    assert s.n_preemptions == 1
    a.check_invariants()
