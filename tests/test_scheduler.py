"""Continuous-batching scheduler: admission policies, pause/resume
(max-utilization), static batching, slot hygiene."""
import numpy as np

from repro.core.kv_cache import PagedAllocator, PrefixCache
from repro.core.metrics import Request
from repro.core.scheduler import ContinuousBatchScheduler, SlotState


def _req(i, n=8, max_new=4):
    return Request(req_id=f"r{i}", prompt_tokens=np.arange(1, n + 1, dtype=np.int32),
                   max_new_tokens=max_new)


def _sched(policy="max_utilization", pages=16, slots=2):
    alloc = PagedAllocator(num_pages=pages, page_size=4, max_pages_per_seq=16)
    return ContinuousBatchScheduler(slots, alloc, policy=policy), alloc


def test_admission_respects_slots():
    s, _ = _sched(slots=2)
    for i in range(4):
        s.add(_req(i))
    d = s.schedule()
    assert len(d.admit) == 2
    assert len(s.waiting) == 2
    assert set(st.slot for st in d.admit) == {0, 1}


def test_admission_respects_pages():
    s, a = _sched(pages=5, slots=4)      # 4 usable pages
    s.add(_req(0, n=8))                   # needs 3 (prompt+1)
    s.add(_req(1, n=8))
    d = s.schedule()
    assert len(d.admit) == 1              # second would overflow pending pages


def test_conservative_reserves_full_output():
    s, _ = _sched(policy="conservative", pages=9, slots=4)
    s.add(_req(0, n=8, max_new=24))       # needs (8+24)/4 = 8 pages
    s.add(_req(1, n=8, max_new=24))
    assert len(s.schedule().admit) == 1


def test_static_waits_for_batch():
    s, a = _sched(policy="static", pages=32, slots=2)
    for i in range(3):
        s.add(_req(i))
    d = s.schedule()
    assert len(d.admit) == 2
    for st in d.admit:
        a.allocate(st.slot, 8)
        st.fed = 8
    assert s.schedule().admit == []       # no refill mid-batch
    s.finish(d.admit[0].slot)
    assert s.schedule().admit == []       # still one running
    s.finish(d.admit[1].slot)
    assert len(s.schedule().admit) == 1   # fresh batch


def test_preemption_pauses_latest_and_requeues():
    s, a = _sched(pages=7, slots=3)       # 6 usable
    for i in range(2):
        s.add(_req(i, n=8))               # 2 pages each
    d = s.schedule()
    for st in d.admit:
        a.allocate(st.slot, 8)
        st.fed = 8
    # burn remaining pages so growth must preempt
    a.allocate(99, 8)
    victim_order = max(st.order for st in s.running.values())
    first = min(s.running.values(), key=lambda st: st.order)
    ok = s.grow_for_decode(first.slot)
    assert ok
    assert len(s.running) == 1
    assert s.waiting[0].preemptions == 1
    assert s.n_preemptions == 1
    a.check_invariants()


def test_make_writable_keeps_partial_copies_across_preempt_retries():
    """Regression: a COW range spanning multiple pages under page pressure
    used to lose the (src, dst) pairs queued before OutOfPages when
    make_writable retried after preempting — the already-detached blocks were
    then skipped and their device copies never ran, leaving fresh pages with
    uninitialized KV where cached prefix content was expected."""
    s, a = _sched(pages=4, slots=3)       # 3 usable pages
    pages = a.allocate(0, 8)              # victim slot: 2 pages, 1 left free
    s.running[0] = SlotState(slot=0, request=_req(0), all_tokens=[1], order=0)
    a.share(1, pages)
    s.running[1] = SlotState(slot=1, request=_req(1), all_tokens=[1], order=1)
    copies = []
    assert s.make_writable(1, 0, 1, copies)
    # first block detached before the pool ran dry; slot 0 was preempted to
    # free the rest, after which block 1 became exclusive (no copy needed)
    assert s.n_preemptions == 1 and 0 not in s.running
    assert len(copies) == 1
    src, dst = copies[0]
    assert src == pages[0] and a.owned(1) == [dst, pages[1]]
    a.check_invariants()


def test_prefix_stats_counted_once_per_admission():
    """Regression: schedule() probes the trie for the head-of-queue request
    every scheduling step; a request stuck waiting on pages used to inflate
    the hit/miss counters (and the reported hit rate) on every re-probe."""
    alloc = PagedAllocator(num_pages=4, page_size=4, max_pages_per_seq=16)
    trie = PrefixCache(alloc)
    s = ContinuousBatchScheduler(2, alloc, prefix_cache=trie)
    alloc.allocate(9, 12)                 # drain the pool
    s.add(_req(0, n=8))
    for _ in range(5):
        assert s.schedule().admit == []   # stuck: no pages
    assert trie.hit_pages == 0 and trie.miss_pages == 0
    alloc.free(9)
    assert len(s.schedule().admit) == 1
    assert trie.miss_pages == 2 and trie.hit_pages == 0   # counted exactly once


def test_admission_counts_revived_retired_pages():
    """Regression: the capacity check compared only fresh-page demand against
    free_pages, but free_pages also counts the LRU pool — reviving retired
    shared pages consumes that same capacity, so admission over-committed and
    leaned on later OutOfPages/preemption to recover."""
    alloc = PagedAllocator(num_pages=6, page_size=4, max_pages_per_seq=16)
    trie = PrefixCache(alloc)
    s = ContinuousBatchScheduler(2, alloc, prefix_cache=trie)
    prefix = list(range(100, 108))        # 2 full pages
    cached = alloc.allocate(9, 8)
    trie.insert(prefix, cached, 2)
    alloc.free(9)                         # both pages retire to the LRU
    alloc.allocate(8, 4)                  # 1 live page -> 2 free + 2 retired
    assert alloc.free_pages == 4
    s.add(Request(req_id="warm", max_new_tokens=4,
                  prompt_tokens=np.array(prefix + list(range(8)), np.int32)))
    # demand: 3 fresh pages (17 tokens -> 5 pages, 2 shared) + 2 revivals = 5
    assert s.schedule().admit == []
    assert alloc.retired_pages == 2       # nothing revived speculatively
    alloc.free(8)                         # free_pages 5: demand now fits
    d = s.schedule()
    assert len(d.admit) == 1 and d.admit[0].cached_tokens == 8
    alloc.check_invariants()
