"""End-to-end behaviour tests for the paper's system: full gateway -> router
-> replicas -> continuous-batching engine path with real streaming, plus the
observability/metrics pipeline, plus a (reduced-mesh) dry-run subprocess."""
import asyncio
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, MetricsSink,
                        Replica, ReplicaRouter, RouterConfig,
                        baseline_gateway_config, scale_gateway_config, summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.data.workload import WorkloadSpec, sample_workload
from repro.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def stack():
    cfg = tiny_config("mixtral-8x7b")      # the paper's model family
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _run(cfg, model, params, gw_cfg, n_requests=10, concurrency=4):
    async def main():
        reps = [Replica(f"r{i}", InferenceEngine(model, params, EngineConfig(
            max_slots=4, page_size=8, num_pages=128, max_seq=128,
            prefill_bucket=16, greedy=True))).start() for i in range(2)]
        sink = MetricsSink()
        router = ReplicaRouter(reps, RouterConfig(policy="least_loaded"), sink=sink)
        gw = Gateway(router, gw_cfg)
        prompts, _ = sample_workload(WorkloadSpec(n_requests=n_requests,
                                                  vocab=cfg.vocab, scale=0.05, seed=7))
        res = await run_workload(gw, prompts, concurrency=concurrency,
                                 max_new_tokens=10, timeout_s=120)
        merge_engine_timestamps(res.requests, gw)
        for r in reps:
            r.stop()
        return res, sink

    return asyncio.run(main())


def test_end_to_end_serving_both_gateways(stack):
    cfg, model, params = stack
    for gw_cfg in (scale_gateway_config(), baseline_gateway_config()):
        res, sink = _run(cfg, model, params, gw_cfg)
        assert all(r.finished for r in res.requests), gw_cfg.name
        assert all(len(r.generated) == 10 for r in res.requests)
        s = summarize(res.requests, res.t_start, res.t_end, 4)
        # lifecycle ordering: t0 <= t1 <= t2 <= t4 <= t5 <= t6, t2 <= t3
        for r in res.requests:
            assert r.t0 <= r.t1 <= r.t2 <= r.t4 <= r.t5 <= r.t6
            assert r.t2 <= r.t3 <= r.t6
        assert s.throughput_tok_s > 0
        counters = sink.snapshot()
        assert counters["requests_completed"] == len(res.requests)
        assert counters["tokens_generated"] == sum(r.n_generated for r in res.requests)


def test_metrics_persisted_to_disk(stack):
    cfg, model, params = stack
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "metrics.jsonl")
        sink = MetricsSink(path)

        async def main():
            rep = Replica("p0", InferenceEngine(model, params, EngineConfig(
                max_slots=2, page_size=8, num_pages=64, max_seq=64,
                prefill_bucket=16, greedy=True))).start()
            router = ReplicaRouter([rep], sink=sink)
            gw = Gateway(router, scale_gateway_config(), sink=sink)
            prompts = [np.arange(1, 9, dtype=np.int32)] * 3
            res = await run_workload(gw, prompts, concurrency=3, max_new_tokens=4)
            rep.stop()
            return res

        asyncio.run(main())
        n = sink.flush()
        assert n >= 3
        lines = [json.loads(ln) for ln in open(path)]
        assert all(ln["kind"] == "request" for ln in lines)
        assert all("engine_latency" in ln for ln in lines)


@pytest.mark.slow
def test_dryrun_subprocess_single_cell():
    """Smoke the real dry-run entry point (512 fake devices) on the cheapest
    cell; asserts lower+compile succeeded and the roofline terms exist."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2.5-3b",
             "--shape", "decode_32k", "--mesh", "single", "--out", d],
            capture_output=True, text=True, timeout=1500, env=env, cwd=REPO)
        assert r.returncode == 0, r.stderr[-2000:]
        out = json.load(open(os.path.join(
            d, "qwen2.5-3b__decode_32k__single__tp.json")))
        assert out["compiled_ok"]
        assert out["roofline"]["dominant"] in ("compute", "memory", "collective")
