"""SSD chunk-scan Pallas kernel vs the chunked-jnp oracle (which is itself
validated against a naive sequential recurrence in test_mamba.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_reference, ssd_scan


def _inputs(rng, Bb, L, H, P, N, dtype=jnp.float32):
    return (jnp.asarray(rng.standard_normal((Bb, L, H, P)), dtype),
            jnp.asarray(rng.uniform(0.01, 0.2, (Bb, L, H)), jnp.float32),
            -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32),
            jnp.asarray(rng.standard_normal((Bb, L, H, N)), dtype),
            jnp.asarray(rng.standard_normal((Bb, L, H, N)), dtype))


@pytest.mark.parametrize("Bb,L,H,P,N,Q", [
    (2, 32, 3, 8, 4, 8),
    (1, 24, 2, 16, 8, 8),     # L not a multiple of Q after slicing below
    (1, 16, 1, 4, 2, 16),     # single chunk
])
def test_ssd_kernel_matches_oracle(rng, Bb, L, H, P, N, Q):
    x, dt, A, B_, C = _inputs(rng, Bb, L, H, P, N)
    ref = ssd_reference(x, dt, A, B_, C, Q)[0]
    out = ssd_scan(x, dt, A, B_, C, Q, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_ssd_kernel_ragged_length(rng):
    x, dt, A, B_, C = _inputs(rng, 2, 27, 2, 8, 4)
    ref = ssd_reference(x, dt, A, B_, C, 8)[0]
    out = ssd_scan(x, dt, A, B_, C, 8, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_ssd_kernel_bf16(rng):
    x, dt, A, B_, C = _inputs(rng, 1, 16, 2, 8, 4, dtype=jnp.bfloat16)
    ref = ssd_reference(x.astype(jnp.float32), dt, A, B_.astype(jnp.float32),
                        C.astype(jnp.float32), 8)[0]
    out = ssd_scan(x, dt, A, B_, C, 8, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=5e-2, rtol=5e-2)
