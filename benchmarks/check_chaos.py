"""Schema + resilience gate for BENCH_chaos.json (ISSUE 10 acceptance):

  * availability >= 0.99 under the seeded fault schedule
  * automatic failover for BOTH an injected crash and an injected stall,
    with zero manual ``handle_failure`` calls and bounded detection latency
  * greedy outputs of retried/failed-over requests bit-match the fault-free
    twin run
  * zero leaked KV pages at exit in both scenarios (dead replicas included)
  * overload run actually shed, expired deadlines, respected the admission
    bound, armed brown-out, and recovered from it by hysteresis

Usage:  python benchmarks/check_chaos.py [BENCH_chaos.json]
Exit 0 on pass; prints every violation and exits 1 otherwise.
"""
from __future__ import annotations

import json
import sys

NUM = (int, float)

FAILOVER_SCHEMA = {
    "n_requests": NUM, "completed": NUM, "availability": NUM,
    "p99_ttft_s": NUM, "auto_failovers": NUM, "manual_failovers": NUM,
    "failover_reasons": list, "failover_latency_max_s": NUM,
    "failovers": list, "retries": NUM, "retry_exhausted": NUM,
    "injected": dict, "leaked_pages": NUM, "greedy_identical": bool,
    "greedy_compared": NUM, "greedy_mismatched": list,
    "p99_ttft_fault_free_s": NUM, "p99_ttft_degradation": NUM,
}

OVERLOAD_SCHEMA = {
    "n_requests": NUM, "max_inflight": NUM, "completed": NUM, "shed": NUM,
    "deadline_exceeded": NUM, "engine_deadline_exceeded": NUM,
    "inflight_max": NUM, "brownout_activations": NUM,
    "brownout_recovered": bool, "brownout_clamped": NUM,
    "p99_ttft_completed_s": NUM, "leaked_pages": NUM,
}

_errors = []


def fail(msg: str) -> None:
    _errors.append(msg)
    print(f"FAIL: {msg}")


def require(obj: dict, schema: dict, where: str) -> None:
    for key, typ in schema.items():
        if key not in obj:
            fail(f"{where}: missing key {key!r}")
        elif not isinstance(obj[key], typ):
            fail(f"{where}: {key!r} should be {typ}, got "
                 f"{type(obj[key]).__name__}={obj[key]!r}")


def check(path: str) -> None:
    with open(path) as f:
        payload = json.load(f)
    for key in ("bench", "git_rev", "timestamp", "seed", "fault_plan",
                "failover", "overload", "summary", "timeline", "rows"):
        if key not in payload:
            fail(f"payload: missing key {key!r}")
    if _errors:
        return
    if payload["bench"] != "chaos":
        fail(f"payload: bench={payload['bench']!r}, expected 'chaos'")

    fo = payload["failover"]
    require(fo, FAILOVER_SCHEMA, "failover")
    if _errors:
        return

    # --- availability + automatic detection --------------------------------
    if fo["availability"] < 0.99:
        fail(f"availability {fo['availability']:.4f} < 0.99 "
             f"({fo['completed']}/{fo['n_requests']})")
    if fo["auto_failovers"] < 2:
        fail(f"auto_failovers {fo['auto_failovers']} < 2 "
             "(crash AND stall must be detected automatically)")
    if fo["manual_failovers"] != 0:
        fail(f"manual_failovers {fo['manual_failovers']} != 0 "
             "(detection must not require manual handle_failure)")
    for reason in ("crash", "stall"):
        if reason not in fo["failover_reasons"]:
            fail(f"failover_reasons {fo['failover_reasons']} missing {reason!r}")
    if not (0.0 < fo["failover_latency_max_s"] < 30.0):
        fail(f"failover_latency_max_s {fo['failover_latency_max_s']} "
             "not in (0, 30)")
    if fo["retry_exhausted"] != 0:
        fail(f"retry_exhausted {fo['retry_exhausted']} != 0 "
             "(retry budget must outlast the submit-error window)")
    for kind in ("crash", "submit_error"):
        if not fo["injected"].get(kind):
            fail(f"injected counters missing {kind!r}: {fo['injected']}")
    if not fo["injected"].get("stall_ticks"):
        fail(f"injected counters missing 'stall_ticks': {fo['injected']}")

    # --- determinism + leaks -----------------------------------------------
    if not fo["greedy_identical"]:
        fail(f"greedy outputs diverged from the fault-free twin: "
             f"{fo['greedy_mismatched']}")
    if fo["greedy_compared"] < fo["n_requests"] * 0.99:
        fail(f"greedy_compared {fo['greedy_compared']} < 99% of "
             f"{fo['n_requests']} (both runs must complete)")
    if fo["leaked_pages"] != 0:
        fail(f"failover scenario leaked {fo['leaked_pages']} KV pages")

    # --- overload / graceful degradation -----------------------------------
    ov = payload["overload"]
    require(ov, OVERLOAD_SCHEMA, "overload")
    if _errors:
        return
    if ov["shed"] < 1:
        fail("overload: no request was shed (bounded admission untested)")
    if ov["deadline_exceeded"] < 1:
        fail("overload: no deadline expired (cancellation path untested)")
    if ov["engine_deadline_exceeded"] < 1:
        fail("overload: engine-side deadline counter is zero")
    if ov["inflight_max"] > ov["max_inflight"]:
        fail(f"overload: inflight_max {ov['inflight_max']} exceeded "
             f"max_inflight {ov['max_inflight']}")
    if ov["brownout_activations"] < 1:
        fail("overload: brown-out never armed under sustained overload")
    if not ov["brownout_recovered"]:
        fail("overload: brown-out did not recover after the burst drained")
    if ov["completed"] < 1:
        fail("overload: nothing completed")
    if not (0.0 < ov["p99_ttft_completed_s"] < 30.0):
        fail(f"overload: p99 TTFT of completed requests "
             f"{ov['p99_ttft_completed_s']} not in (0, 30) s")
    if ov["leaked_pages"] != 0:
        fail(f"overload scenario leaked {ov['leaked_pages']} KV pages "
             "(shed/deadline cancellation must free pages)")

    # --- timeline carries the resilience counters --------------------------
    summary = payload["summary"]
    for key in ("shed", "retries", "deadline_exceeded", "failovers",
                "failover_latency_max_s", "failover_latency_mean_s"):
        if key not in summary:
            fail(f"summary: missing resilience key {key!r}")


def check_html(path: str) -> None:
    try:
        with open(path) as f:
            html = f.read()
    except OSError as e:
        fail(f"dashboard: {e}")
        return
    for needle in ("Shed", "Failovers", "Resilience"):
        if needle not in html:
            fail(f"dashboard: missing {needle!r} tile/chart")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_chaos.json"
    check(path)
    check_html(path.replace(".json", ".html"))
    if _errors:
        print(f"\n{len(_errors)} violation(s) in {path}")
        return 1
    print(f"OK: {path} passes the chaos resilience gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
