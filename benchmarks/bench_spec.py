"""Speculative-decoding perf trajectory: decode throughput / TPOT with
prompt-lookup drafting + multi-token verify (DESIGN.md §3) vs the plain
decode path, at 1 / 8 / 32 concurrent requests.

Workload: repetition-friendly (RAG-style extractive) traffic —
``sample_workload`` with ``extractive_frac``/``boilerplate_frac`` builds
prompts shaped like retrieval traffic (grounding passage repeated around a
query; templated boilerplate), then an untimed calibration pass probes a
candidate pool with the real drafter and keeps the prompts whose greedy
continuations are the most draft-matchable. The tiny random-weight bench
model attaches no meaning to token identity, so the selection step is what
reproduces the serving-level property of extractive traffic — outputs that
copy spans already in context, exactly what prompt-lookup speculation
exploits in production (vLLM's ``[ngram]`` speculative model). Baseline and
speculative runs execute the SAME selected requests; greedy outputs are
compared token-for-token and reported per row.

``run.py`` persists these rows to ``BENCH_spec.json``; the acceptance gate
for the speculative-decoding work is >= 1.5x decode token throughput at c8.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import get_model, row
from repro.core import EngineConfig, InferenceEngine, Request, now, summarize
from repro.core.spec import PromptLookupDraft
from repro.data.workload import WorkloadSpec, sample_workload

CONCS = [1, 8, 32]
# draft length is a per-deployment-point knob: long drafts amortize per-step
# overhead at low batch; at high batch the verify chunk's extra positions
# compete with batch parallelism for the same FLOPs, so k shrinks
SPEC_KS = {1: 8, 8: 8, 32: 3}
PAGE = 8
MAX_SEQ = 384
PROBE_NEW = 48                 # calibration probe length (untimed)


def _engine(model, params, c: int, spec: bool, k: int) -> InferenceEngine:
    return InferenceEngine(model, params, EngineConfig(
        max_slots=c, page_size=PAGE, num_pages=2048, max_seq=MAX_SEQ,
        prefill_bucket=16, token_budget=c * (1 + k) + 32, greedy=True,
        enable_speculative=spec, spec_k=k))


def _drafty_prompts(cfg, model, params, n: int, c: int, k: int,
                    seed: int) -> List[np.ndarray]:
    """Calibrated repetition-friendly prompts: sample a 3x pool of
    extractive/boilerplate-shaped prompts, probe each with a short untimed
    greedy generation, score the probe with the drafter itself (mean
    committed tokens per draft call), keep the top ``n``."""
    pool, _ = sample_workload(WorkloadSpec(
        n_requests=3 * n, vocab=cfg.vocab, prompt_median=1150, prompt_sigma=0.1,
        scale=0.04, seed=seed, extractive_frac=0.5, boilerplate_frac=0.5))
    eng = _engine(model, params, min(max(c, 8), 32), spec=False, k=k)
    probes = eng.generate([Request(req_id=f"probe{seed}-{i}", prompt_tokens=p,
                                   max_new_tokens=PROBE_NEW)
                           for i, p in enumerate(pool)])
    ds = PromptLookupDraft()

    def score(prompt: np.ndarray, gen: List[int]) -> float:
        hist = list(map(int, prompt)) + list(gen)
        pos, calls, commits = len(prompt) + 1, 0, 0
        while pos < len(hist):
            draft = ds.propose(hist[:pos], k)
            na = 0
            for j, t in enumerate(draft):
                if pos + j < len(hist) and hist[pos + j] == t:
                    na += 1
                else:
                    break
            calls, commits, pos = calls + 1, commits + na + 1, pos + na + 1
        return commits / max(calls, 1)

    order = np.argsort([score(pool[i], probes[i].generated)
                        for i in range(len(pool))])[::-1]
    return [pool[i] for i in order[:n]]


def _prewarm(model, params, c: int, k: int, prompts: List[np.ndarray]) -> None:
    """Untimed compile pass (throwaway engines, same shapes as the timed
    runs). The speculative engine's chunk width follows a compiled-width
    ladder, so every ladder width is exercised explicitly — adaptive K may
    not visit all of them during a short warmup generation."""
    base = _engine(model, params, c, spec=False, k=k)
    base.generate([Request(req_id=f"wb{c}-{i}", prompt_tokens=p, max_new_tokens=8)
                   for i, p in enumerate(prompts[:c])])
    eng = _engine(model, params, c, spec=True, k=k)
    zeros = np.zeros((c,), np.int32)
    for width in eng._spec_widths:
        _, _, eng.cache = eng._spec_jit_for(width)(
            eng.params, eng.cache, jax.numpy.zeros((c, width), jax.numpy.int32),
            jax.numpy.asarray(zeros), jax.numpy.asarray(zeros),
            jax.numpy.arange(c, dtype=jax.numpy.int32),
            jax.numpy.zeros((c,), bool), jax.numpy.asarray(eng.page_table),
            jax.random.PRNGKey(0))
    eng.generate([Request(req_id=f"ws{c}-{i}", prompt_tokens=p, max_new_tokens=8)
                  for i, p in enumerate(prompts[:c])])


def _run_once(model, params, prompts: List[np.ndarray], c: int, *, spec: bool,
              k: int, max_new: int, tag: str):
    eng = _engine(model, params, c, spec, k)
    reqs = [Request(req_id=f"{tag}{i}", prompt_tokens=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    t0 = now()
    eng.generate(reqs)
    return summarize(reqs, t0, now(), c, extras=eng.stats()), reqs


def run(quick: bool = True):
    cfg, model, params = get_model()
    max_new = 192 if quick else 256
    rows = []
    for c in CONCS:
        n = max(2 * c, 4)
        k = SPEC_KS[c]
        prompts = _drafty_prompts(cfg, model, params, n, c, k, seed=c)
        _prewarm(model, params, c, k, prompts)

        base, base_reqs = _run_once(model, params, prompts, c, spec=False,
                                    k=k, max_new=max_new, tag=f"base{c}-")
        spec, spec_reqs = _run_once(model, params, prompts, c, spec=True,
                                    k=k, max_new=max_new, tag=f"spec{c}-")

        identical = all(b.generated == s.generated
                        for b, s in zip(base_reqs, spec_reqs))
        speedup = spec.throughput_tok_s / max(base.throughput_tok_s, 1e-9)
        rows.append(row(
            f"spec.scalellm.c{c}.decode_tput",
            1e6 / max(spec.throughput_tok_s, 1e-9),
            spec_throughput_tok_s=spec.throughput_tok_s,
            base_throughput_tok_s=base.throughput_tok_s,
            speedup=speedup,
            spec_tpot_us=spec.mean["tbt"] * 1e6,
            base_tpot_us=base.mean["tbt"] * 1e6,
            acceptance_rate=spec.extras.get("spec_acceptance_rate", 0.0),
            drafted_tokens=spec.extras.get("drafted_tokens", 0),
            accepted_tokens=spec.extras.get("accepted_tokens", 0),
            spec_steps=spec.extras.get("spec_steps", 0),
            base_steps=base.extras.get("steps", 0),
            greedy_identical=identical,
            concurrency=c,
            n_requests=n,
            max_new=max_new,
            spec_k=k,
        ))
    return rows
