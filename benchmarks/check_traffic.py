"""CI gate for the traffic bench artifacts: validates ``BENCH_traffic.json``
against the expected schema (required keys, window fields, non-empty
timeline) and sanity-checks the ``BENCH_traffic.html`` dashboard. No
dependencies; exits non-zero with a readable message on the first violation.

Usage:  python benchmarks/check_traffic.py [json_path] [html_path]
"""
from __future__ import annotations

import json
import sys

NUM = (int, float)
SUMMARY_SCHEMA = {
    "window_s": NUM, "n_windows": int, "n_steps": int, "n_requests": int,
    "slo": dict, "slo_attainment": (*NUM, type(None)),
    "p50_ttft_s": NUM, "p99_ttft_s": NUM, "p50_tbt_s": NUM, "p99_tbt_s": NUM,
    "throughput_tok_s": NUM, "preemptions": int, "completed_tokens": int,
}
WINDOW_SCHEMA = {
    "t": NUM, "window_s": NUM, "steps": int, "completed": int, "admitted": int,
    "throughput_tok_s": NUM, "decode_tok_s": NUM, "prefill_tok_s": NUM,
    "p50_ttft_s": NUM, "p99_ttft_s": NUM, "p50_tbt_s": NUM, "p99_tbt_s": NUM,
    "p50_queue_wait_s": NUM, "p99_queue_wait_s": NUM,
    "queue_depth_mean": NUM, "queue_depth_max": int,
    "occupancy_frac": NUM, "budget_util": NUM, "kv_util_mean": NUM,
    "busy_frac": NUM, "preemptions_per_s": NUM, "cow_pages_per_s": NUM,
    "spec_acceptance": NUM, "slo_attainment": (*NUM, type(None)),
    "ttft_ok_frac": (*NUM, type(None)), "tbt_ok_frac": (*NUM, type(None)),
}


def fail(msg: str) -> None:
    print(f"check_traffic: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def require(obj: dict, schema: dict, where: str) -> None:
    for key, typ in schema.items():
        if key not in obj:
            fail(f"{where}: missing key {key!r}")
        if not isinstance(obj[key], typ):
            fail(f"{where}: {key!r} has type {type(obj[key]).__name__}, "
                 f"expected {typ}")


def check_json(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    for key in ("bench", "git_rev", "timestamp", "schedule", "slo",
                "window_s", "summary", "timeline", "traces_exported", "rows"):
        if key not in d:
            fail(f"{path}: missing top-level key {key!r}")
    if d["bench"] != "traffic":
        fail(f"{path}: bench is {d['bench']!r}, expected 'traffic'")
    require(d["summary"], SUMMARY_SCHEMA, "summary")
    if not d["timeline"]:
        fail("timeline is empty — the run produced no windows")
    for i, w in enumerate(d["timeline"]):
        require(w, WINDOW_SCHEMA, f"timeline[{i}]")
        for frac in ("occupancy_frac", "busy_frac", "kv_util_mean"):
            if not 0.0 <= w[frac] <= 1.0 + 1e-9:
                fail(f"timeline[{i}].{frac} = {w[frac]} out of [0, 1]")
    ts = [w["t"] for w in d["timeline"]]
    if ts != sorted(ts):
        fail("timeline windows are not time-ordered")
    s = d["summary"]
    if s["n_requests"] <= 0:
        fail("summary.n_requests is 0 — nothing completed")
    if s["n_steps"] <= 0:
        fail("summary.n_steps is 0 — no engine iterations profiled")
    if d["traces_exported"] <= 0:
        fail("traces_exported is 0 — the tracer exported no request traces")
    if s["throughput_tok_s"] <= 0:
        fail("summary.throughput_tok_s is 0")
    names = [r.get("name") for r in d["rows"]]
    for want in ("traffic.completed", "traffic.slo", "traffic.throughput",
                 "traffic.tracing_overhead"):
        if want not in names:
            fail(f"rows: missing {want!r}")
    return d


def check_html(path: str, d: dict) -> None:
    src = open(path).read()
    if "<!doctype html>" not in src.lower():
        fail(f"{path}: not an HTML document")
    n_charts = src.count('<svg class="chart"')
    if n_charts < 6:
        fail(f"{path}: only {n_charts} charts rendered, expected >= 6")
    if src.count('class="tile"') < 6:
        fail(f"{path}: stat tiles missing")
    if "data-points" not in src:
        fail(f"{path}: charts carry no embedded data payloads")
    if 'class="data"' not in src:
        fail(f"{path}: accessible data tables missing")
    if "prefers-color-scheme: dark" not in src:
        fail(f"{path}: no dark-mode theme block")


def main() -> None:
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_traffic.json"
    html_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_traffic.html"
    d = check_json(json_path)
    check_html(html_path, d)
    s = d["summary"]
    print(f"check_traffic: OK — {s['n_requests']} requests, "
          f"{s['n_windows']} windows, {s['n_steps']} steps, "
          f"{d['traces_exported']} traces, "
          f"{s['throughput_tok_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
