"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. ``--full`` runs the paper-scale
concurrency sweeps (slow on CPU); default is the quick profile.

The ``prefill`` bench additionally persists its rows to ``BENCH_prefill.json``
(TTFT/TPOT at 8/32/64 concurrency), the ``prefix`` bench to
``BENCH_prefix.json`` (warm-vs-cold TTFT under a shared system prompt), and
the ``spec`` bench to ``BENCH_spec.json`` (speculative-vs-plain decode
throughput) so subsequent PRs have a perf trajectory to regress against.
The ``traffic`` bench persists its own ``BENCH_traffic.{json,html,md}``
(windowed SLO timeline + dashboard — see bench_traffic.py).
Persisted payloads are stamped with the git revision and a UTC timestamp.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

PERSIST_JSON = {"prefill": "BENCH_prefill.json", "prefix": "BENCH_prefix.json",
                "spec": "BENCH_spec.json"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names "
                         "(fig2,fig5,fig6,fig7,table1,fig8,kernels,prefill,"
                         "prefix,spec,traffic,chaos)")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_chaos, bench_fig2_breakdown,
                            bench_fig5_endpoints, bench_fig6_breakdown,
                            bench_fig7_throughput, bench_fig8_parallelism,
                            bench_kernels, bench_prefill, bench_prefix,
                            bench_spec, bench_table1_streaming, bench_traffic)
    from benchmarks.common import stamp, warmup

    benches = {
        "fig2": bench_fig2_breakdown,
        "fig5": bench_fig5_endpoints,
        "fig6": bench_fig6_breakdown,
        "fig7": bench_fig7_throughput,
        "table1": bench_table1_streaming,
        "fig8": bench_fig8_parallelism,
        "kernels": bench_kernels,
        "prefill": bench_prefill,
        "prefix": bench_prefix,
        "spec": bench_spec,
        "traffic": bench_traffic,   # writes BENCH_traffic.{json,html,md} itself
        "chaos": bench_chaos,       # writes BENCH_chaos.{json,html,md} itself
    }
    selected = args.only.split(",") if args.only else list(benches)
    unknown = [n for n in selected if n not in benches]
    if unknown:
        ap.error(f"unknown bench name(s): {', '.join(unknown)} "
                 f"(registered: {', '.join(benches)})")

    print("name,us_per_call,derived")
    warmup()
    for name in selected:
        mod = benches[name]
        t0 = time.time()
        try:
            rows = mod.run(quick=quick)
        except Exception as e:  # a failing bench must not hide the others
            print(f"{name}.ERROR,0,\"{type(e).__name__}: {e}\"", flush=True)
            continue
        for r in rows:
            derived = json.dumps(r["derived"], default=str).replace('"', "'")
            print(f"{r['name']},{r['us_per_call']:.1f},\"{derived}\"", flush=True)
        if name in PERSIST_JSON:
            with open(PERSIST_JSON[name], "w") as f:
                json.dump({"bench": name, "quick": quick, **stamp(),
                           "rows": rows}, f, indent=2, default=str)
            print(f"# wrote {PERSIST_JSON[name]}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
