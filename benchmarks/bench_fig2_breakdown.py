"""Paper Fig 2: baseline-solution breakdown — engine latency dominates at low
concurrency; gateway latency dominates at high concurrency (with the
FastAPI-style gateway)."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint


def run(quick: bool = True):
    rows = []
    concs = [2, 16] if quick else [4, 32, 64]
    for style in ("hf", "scalellm"):
        for c in concs:
            n = min(3 * c, 24 if quick else 20 * c)
            s = run_endpoint(style, "baseline", concurrency=c, n_requests=n,
                             max_new=8, timeout_s=45 if style == "hf" else 60)
            rows.append(row(
                f"fig2.{style}+fastapi_gw.c{c}.engine_latency",
                s.mean["engine_latency"] * 1e6,
                gateway_latency_us=s.mean["gateway_latency"] * 1e6,
                avg_latency_us=s.mean["avg_latency"] * 1e6,
                throughput_tok_s=s.throughput_tok_s,
                timeout_frac=s.timeout_frac,
            ))
    return rows
