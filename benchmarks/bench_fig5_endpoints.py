"""Paper Fig 5: endpoint throughput comparison across concurrency levels.
ScaleLLM (engine+gateway optimized) vs the hf and vllm-class endpoints."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint

ENDPOINTS = [("hf", "baseline"), ("vllm", "baseline"), ("scalellm", "scale")]


def run(quick: bool = True):
    rows = []
    concs = [1, 4, 16] if quick else [1, 4, 16, 64]
    for style, gw in ENDPOINTS:
        for c in concs:
            if style == "hf" and c > 4:
                c_eff = c  # hf times out at high concurrency -- measure anyway
            n = min(2 * c, 16 if quick else 20 * c)
            s = run_endpoint(style, gw, concurrency=c, n_requests=n, max_new=8,
                             timeout_s=30 if style == "hf" else 60)
            rows.append(row(
                f"fig5.{style}.c{c}.throughput",
                1e6 / max(s.throughput_tok_s, 1e-9),   # us per token
                throughput_tok_s=s.throughput_tok_s,
                timeout_frac=s.timeout_frac,
            ))
    return rows
