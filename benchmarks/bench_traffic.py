"""Bursty-traffic serving bench: open-loop Poisson arrivals with periodic
bursts replayed against a tracer-enabled replica, producing the windowed SLO
timeline (``BENCH_traffic.json``) plus a self-contained HTML dashboard
(``BENCH_traffic.html``) and a markdown twin (``BENCH_traffic.md``).

Also measures tracing overhead: the same closed-loop workload at c32 with the
tracer + iteration profiler enabled vs fully disabled (acceptance target:
< 2% throughput delta when disabled — the ``if tracer:`` guard and the
profiling wrapper must be near-free).

Standalone smoke entry for CI:  ``python benchmarks/bench_traffic.py --smoke``
(tiny schedule, same artifacts, seconds not minutes).
"""
from __future__ import annotations

import asyncio
import json
import os
import tempfile
from typing import Optional

from benchmarks.common import build_replicas, get_model, row, stamp
from repro.core import (Gateway, MetricsSink, ReplicaRouter, RouterConfig,
                        SLOConfig, TimelineAggregator, Tracer,
                        scale_gateway_config, summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.dashboard import render_dashboard, render_markdown
from repro.data.workload import WorkloadSpec, sample_arrivals, sample_workload

OUT_JSON = "BENCH_traffic.json"
OUT_HTML = "BENCH_traffic.html"
OUT_MD = "BENCH_traffic.md"


def _serve(*, n_requests: int, arrival_rate: float, burst_mult: float,
           burst_period_s: float, max_new: int, timeout_s: float,
           tracing: bool, seed: int, window_s: float,
           slo: Optional[SLOConfig] = None, trace_path: Optional[str] = None):
    """One serving run. Open loop when ``arrival_rate > 0`` (the schedule is
    part of the workload spec), closed loop at c32 otherwise. Returns
    (client result, aggregator, n trace records exported)."""
    cfg, _, _ = get_model()
    tracer = Tracer(enabled=tracing)
    sink = MetricsSink(path=trace_path,
                       flush_interval_s=0.2 if trace_path else None)
    fleet = build_replicas(
        "scalellm", 1, tracer=tracer,
        engine_overrides={"profile_steps": tracing})
    router = ReplicaRouter(fleet, RouterConfig(policy="least_loaded"),
                           sink=sink, tracer=tracer)
    gw = Gateway(router, scale_gateway_config())
    spec = WorkloadSpec(n_requests=n_requests, vocab=cfg.vocab, scale=0.04,
                        seed=seed, arrival_rate=arrival_rate,
                        burst_mult=burst_mult, burst_period_s=burst_period_s,
                        burst_duty=0.3)
    prompts, _ = sample_workload(spec)
    arrivals = sample_arrivals(spec) if arrival_rate > 0 else None

    async def main():
        return await run_workload(gw, prompts, concurrency=32,
                                  max_new_tokens=max_new, timeout_s=timeout_s,
                                  arrivals=arrivals)

    res = asyncio.run(main())
    merge_engine_timestamps(res.requests, gw)
    agg = TimelineAggregator(window_s=window_s, slo=slo)
    agg.add_steps(fleet[0].step_records())
    for r in res.requests:
        if r.finished:
            agg.add_request(r)
    for rep in fleet:
        rep.stop()
    sink.close()
    n_traces = 0
    if trace_path and os.path.exists(trace_path):
        with open(trace_path) as f:
            n_traces = sum(1 for line in f
                           if json.loads(line).get("kind") == "trace")
    return res, agg, n_traces


def run(quick: bool = True, smoke: bool = False):
    if smoke:
        n, rate, max_new, window_s, timeout = 8, 24.0, 6, 0.25, 30.0
    elif quick:
        n, rate, max_new, window_s, timeout = 24, 12.0, 8, 0.5, 60.0
    else:
        n, rate, max_new, window_s, timeout = 96, 16.0, 10, 1.0, 120.0
    slo = SLOConfig(ttft_target_s=2.0, tbt_target_s=0.25)

    trace_file = tempfile.NamedTemporaryFile(
        suffix=".jsonl", prefix="traffic_trace_", delete=False)
    trace_file.close()
    try:
        res, agg, n_traces = _serve(
            n_requests=n, arrival_rate=rate, burst_mult=3.0,
            burst_period_s=2.0, max_new=max_new, timeout_s=timeout,
            tracing=True, seed=7, window_s=window_s, slo=slo,
            trace_path=trace_file.name)
    finally:
        os.unlink(trace_file.name)
    timeline = agg.timeline()
    summary = agg.summary()
    done = sum(1 for r in res.requests if r.finished)

    # --- tracing overhead: closed loop c32, tracer+profiler on vs off -------
    n_ovh = 8 if smoke else (16 if quick else 64)
    s_on = summarize(*_ovh_run(n_ovh, tracing=True))
    s_off = summarize(*_ovh_run(n_ovh, tracing=False))
    overhead = (s_off.throughput_tok_s / s_on.throughput_tok_s - 1.0
                if s_on.throughput_tok_s else 0.0)

    rows = [
        row("traffic.completed", 0.0, completed=done, total=n,
            traces_exported=n_traces, windows=summary["n_windows"],
            steps=summary["n_steps"]),
        row("traffic.slo", 0.0,
            slo_attainment=summary["slo_attainment"],
            p50_ttft_s=summary["p50_ttft_s"], p99_ttft_s=summary["p99_ttft_s"],
            p50_tbt_s=summary["p50_tbt_s"], p99_tbt_s=summary["p99_tbt_s"]),
        row("traffic.throughput", 0.0,
            tok_s=summary["throughput_tok_s"],
            preemptions=summary["preemptions"]),
        row("traffic.tracing_overhead", 0.0,
            tok_s_tracing_on=s_on.throughput_tok_s,
            tok_s_tracing_off=s_off.throughput_tok_s,
            off_vs_on_gain=overhead),
    ]

    payload = {"bench": "traffic", "quick": quick, "smoke": smoke, **stamp(),
               "schedule": {"n_requests": n, "arrival_rate": rate,
                            "burst_mult": 3.0, "burst_period_s": 2.0,
                            "burst_duty": 0.3, "max_new_tokens": max_new},
               "slo": {"ttft_target_s": slo.ttft_target_s,
                       "tbt_target_s": slo.tbt_target_s},
               "window_s": window_s,
               "summary": summary, "timeline": timeline,
               "traces_exported": n_traces, "rows": rows}
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    title = "ScaleLLM serving timeline (bursty open-loop traffic)"
    with open(OUT_HTML, "w") as f:
        f.write(render_dashboard(timeline, summary, title))
    with open(OUT_MD, "w") as f:
        f.write(render_markdown(timeline, summary, title))
    return rows


def _ovh_run(n_requests: int, *, tracing: bool):
    res, _, _ = _serve(n_requests=n_requests, arrival_rate=0.0,
                       burst_mult=1.0, burst_period_s=0.0, max_new=8,
                       timeout_s=60.0, tracing=tracing, seed=11,
                       window_s=1.0)
    return res.requests, res.t_start, res.t_end, 32


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny schedule for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    from benchmarks.common import warmup
    warmup()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        print(f"{r['name']}: {json.dumps(r['derived'], default=str)}")
    print(f"wrote {OUT_JSON}, {OUT_HTML}, {OUT_MD}")


if __name__ == "__main__":
    main()
