"""Paper Table 1: streaming TTFT / TBT vs concurrency for the three
endpoints, with the paper's 60s timeout rule."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint

ENDPOINTS = [("hf", "baseline"), ("vllm", "baseline"), ("scalellm", "scale")]


def run(quick: bool = True):
    rows = []
    concs = [1, 4, 8] if quick else [1, 2, 4, 8, 16, 32, 64]
    for style, gw in ENDPOINTS:
        for c in concs:
            n = min(2 * c, 12 if quick else 20 * c)
            s = run_endpoint(style, gw, concurrency=c, n_requests=n, max_new=10,
                             timeout_s=30 if style == "hf" else 60)
            rows.append(row(
                f"table1.{style}.c{c}.ttft",
                s.mean["ttft_user"] * 1e6,
                tbt_us=s.mean["tbt"] * 1e6,
                timeout_frac=s.timeout_frac,
            ))
    return rows
