"""Paper Fig 7: throughput for engine x gateway combinations vs concurrency —
the cumulative impact of engine and gateway optimizations."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint

GRID = [("vllm", "baseline"), ("vllm", "scale"),
        ("scalellm", "baseline"), ("scalellm", "scale")]


def run(quick: bool = True):
    rows = []
    concs = [2, 8] if quick else [1, 4, 16, 64, 256]
    for style, gw in GRID:
        for c in concs:
            n = min(2 * c, 16 if quick else 20 * c)
            s = run_endpoint(style, gw, concurrency=c, n_requests=n, max_new=8)
            rows.append(row(
                f"fig7.{style}_engine+{gw}_gw.c{c}.throughput",
                1e6 / max(s.throughput_tok_s, 1e-9),
                throughput_tok_s=s.throughput_tok_s,
            ))
    return rows
