"""Shared-prefix KV cache perf trajectory: warm-vs-cold TTFT with a 2-page
shared system prompt (DESIGN.md §2) at 8 / 32 / 64 concurrent requests.

Cold = prefix cache disabled, every request prefills the full prompt.
Warm = prefix cache enabled and the trie pre-seeded with the system prompt,
so each request skips the shared pages and only prefills its own tail.

``run.py`` persists these rows to ``BENCH_prefix.json``; the acceptance gate
for the prefix-cache work is mean warm TTFT <= 0.5x cold TTFT.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import get_model, row
from repro.core import EngineConfig, InferenceEngine, Request, now, summarize
from repro.data.workload import WorkloadSpec, sample_workload

CONCS = [8, 32, 64]
PAGE = 16
PREFIX_PAGES = 2          # "2-page shared system prompt" (2 x 16 = 32 tokens)
MAX_NEW = 8


def _prompts(cfg, n: int, seed: int) -> List[np.ndarray]:
    prompts, _ = sample_workload(WorkloadSpec(
        n_requests=n, vocab=cfg.vocab, scale=0.04, seed=seed,
        shared_prefix_len=PREFIX_PAGES * PAGE))
    return prompts


def _engine(model, params, c: int, cache: bool) -> InferenceEngine:
    return InferenceEngine(model, params, EngineConfig(
        max_slots=c, page_size=PAGE, num_pages=1024, max_seq=192,
        prefill_bucket=16, greedy=True, enable_prefix_cache=cache))


def _run_once(model, params, prompts: List[np.ndarray], c: int, *,
              cache: bool, tag: str):
    """Fresh engine, trie pre-seeded with the system prompt when ``cache``.
    Compiled prefill/decode fns are shared across engines of the same config,
    so a prior untimed pass removes JIT compilation from the timing."""
    eng = _engine(model, params, c, cache)
    if cache:
        # seed the trie: one request carrying just the shared system prompt
        eng.generate([Request(req_id=f"{tag}-seed",
                              prompt_tokens=prompts[0][: PREFIX_PAGES * PAGE + 2],
                              max_new_tokens=2)])
    reqs = [Request(req_id=f"{tag}{i}", prompt_tokens=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    t0 = now()
    eng.generate(reqs)
    return summarize(reqs, t0, now(), c, extras=eng.stats())


def run(quick: bool = True):
    cfg, model, params = get_model()
    rows = []
    for c in CONCS:
        n = max(16, c)                      # >= the 16-request acceptance case
        prompts = _prompts(cfg, n, seed=c)

        # untimed compile passes (throwaway engines, same shapes as the timed
        # runs) so neither mode's timing includes XLA compilation
        _run_once(model, params, prompts, c, cache=False, tag="jitc")
        _run_once(model, params, prompts, c, cache=True, tag="jitw")

        cold = _run_once(model, params, prompts, c, cache=False, tag="cold")
        warm = _run_once(model, params, prompts, c, cache=True, tag="warm")

        ratio = warm.mean["ttft"] / max(cold.mean["ttft"], 1e-9)
        rows.append(row(
            f"prefix.scalellm.c{c}.warm_ttft",
            warm.mean["ttft"] * 1e6,
            cold_ttft_us=cold.mean["ttft"] * 1e6,
            warm_over_cold=ratio,
            p99_warm_ttft_us=warm.p99["ttft"] * 1e6,
            p99_cold_ttft_us=cold.p99["ttft"] * 1e6,
            warm_throughput_tok_s=warm.throughput_tok_s,
            cold_throughput_tok_s=cold.throughput_tok_s,
            prefix_hit_rate=warm.extras.get("prefix_hit_rate", 0.0),
            prefix_cached_tokens=warm.extras.get("prefix_cached_tokens", 0),
            cow_copies=warm.extras.get("cow_copies", 0),
            evicted_pages=warm.extras.get("evicted_pages", 0),
            concurrency=c,
            n_requests=n,
            prefix_pages=PREFIX_PAGES,
        ))
    return rows
