"""Chunked-prefill perf trajectory: streaming TTFT / TPOT on the ScaleLLM
endpoint at 8 / 32 / 64 concurrent requests with mixed prompt lengths.

``run.py`` persists these rows to ``BENCH_prefill.json`` so later PRs have a
baseline to regress against (acceptance gate for the chunked-prefill work:
TTFT at high concurrency must not regress)."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint

CONCS = [8, 32, 64]


def run(quick: bool = True):
    rows = []
    for c in CONCS:
        n = min(2 * c, 24) if quick else 2 * c
        s = run_endpoint("scalellm", "scale", concurrency=c, n_requests=n,
                         max_new=10, timeout_s=120)
        rows.append(row(
            f"prefill.scalellm.c{c}.ttft",
            s.mean["ttft_user"] * 1e6,
            tpot_us=s.mean["tbt"] * 1e6,
            p99_ttft_us=s.p99["ttft_user"] * 1e6,
            throughput_tok_s=s.throughput_tok_s,
            timeout_frac=s.timeout_frac,
            concurrency=c,
            n_requests=n,
        ))
    return rows
