"""Kernel/engine micro-benchmarks (CPU wall time of the executable paths;
Pallas TPU kernels are validated in interpret mode — their perf story is the
roofline, not CPU timing)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_model, row
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.metrics import Request
from repro.kernels.flash_attention import flash_attention


def _time(fn, n=5):
    fn()                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(quick: bool = True):
    rows = []
    r = np.random.default_rng(0)

    # flash attention (chunked-xla path, what the CPU engine executes)
    B, S, H, Hkv, D = 2, 256, 4, 2, 32
    q = jnp.asarray(r.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, Hkv, D)), jnp.float32)
    t = _time(lambda: jax.block_until_ready(
        flash_attention(q, k, v, block_q=64, block_kv=64, backend="xla")))
    rows.append(row("kernels.flash_attention_xla.B2S256", t * 1e6,
                    flops=4 * B * S * S * H * D))

    # one engine decode iteration at full slots
    cfg, model, params = get_model()
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=8, page_size=8, num_pages=256, max_seq=128,
        prefill_bucket=16, greedy=True))
    reqs = [Request(req_id=f"k{i}", prompt_tokens=r.integers(1, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=64) for i in range(8)]
    for q_ in reqs:
        eng.submit(q_)
    eng.step()                              # admissions + first decode (compiles)
    t = _time(lambda: eng.step(), n=10)
    rows.append(row("engine.decode_step.8slots", t * 1e6,
                    tokens_per_s=8 / t))

    # prefill at two buckets
    for L in (16, 64):
        req = Request(req_id=f"p{L}", prompt_tokens=r.integers(1, cfg.vocab, L - 2).astype(np.int32),
                      max_new_tokens=1)
        eng2 = InferenceEngine(model, params, EngineConfig(
            max_slots=1, page_size=8, num_pages=256, max_seq=128,
            prefill_bucket=16, greedy=True))
        eng2.generate([req])               # includes compile
        req2 = Request(req_id=f"p{L}b", prompt_tokens=r.integers(1, cfg.vocab, L - 2).astype(np.int32),
                       max_new_tokens=1)
        t0 = time.perf_counter()
        eng2.generate([req2])
        t = time.perf_counter() - t0
        rows.append(row(f"engine.prefill.bucket{L}", t * 1e6, prompt_len=L - 2))
    return rows
