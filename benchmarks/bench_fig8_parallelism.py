"""Paper Fig 8 / Exp4: parallelism comparisons.

(a) Replica parallelism (REAL, CPU): equal total slots deployed as
    1 replica x 8 slots vs 2 x 4 vs 4 x 2 — low concurrency favors the big
    replica, high concurrency favors many replicas (the paper's crossover).

(b) TP x EP computation parallelism (ANALYTIC, TPU roofline): reads the
    dry-run JSONs for mixtral/dbrx decode cells lowered with moe=tp (pure
    tensor parallel — the paper's baseline) vs moe=ep (hybrid) and compares
    the roofline step-time bound => tokens/s. This reproduces Exp4's
    conclusion from the compiled artifacts, re-derived for TPU v5e ICI
    (DESIGN.md §2: crossovers are re-derived, not copied from NVLink).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import build_replicas, row, run_endpoint

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def replica_sweep(quick: bool = True):
    rows = []
    layouts = [(1, 8), (2, 4), (4, 2)]          # (replicas, slots) — equal compute
    concs = [2, 12] if quick else [2, 8, 32, 128]
    for n_rep, slots in layouts:
        fleet = build_replicas("scalellm", n_rep, max_slots=slots)
        try:
            for c in concs:
                n = min(2 * c, 16 if quick else 20 * c)
                s = run_endpoint("scalellm", "scale", concurrency=c, n_requests=n,
                                 max_new=8, replicas=fleet)
                rows.append(row(
                    f"fig8ab.replicas{n_rep}xslots{slots}.c{c}.throughput",
                    1e6 / max(s.throughput_tok_s, 1e-9),
                    throughput_tok_s=s.throughput_tok_s,
                ))
        finally:
            for r in fleet:
                r.stop()
    return rows


def tp_ep_roofline(quick: bool = True):
    rows = []
    for arch in ("mixtral-8x7b", "dbrx-132b"):
        for moe in ("tp", "ep"):
            path = os.path.join(DRYRUN_DIR, f"{arch}__decode_32k__single__{moe}.json")
            if not os.path.exists(path):
                continue
            d = json.load(open(path))
            if "roofline" not in d:
                continue
            r = d["roofline"]
            bound = max(r["compute_s"], r["memory_floor_s"], r["collective_s"])
            tok_s = 128 / bound          # decode_32k batch over the bound
            rows.append(row(
                f"fig8cd.{arch}.decode_32k.moe_{moe}.step_bound",
                bound * 1e6,
                tokens_per_s_bound=tok_s,
                dominant=r["dominant"],
                compute_s=r["compute_s"], memory_floor_s=r["memory_floor_s"],
                collective_s=r["collective_s"],
            ))
    return rows


def run(quick: bool = True):
    return replica_sweep(quick) + tp_ep_roofline(quick)
