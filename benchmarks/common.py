"""Shared harness for the paper-figure benchmarks.

Endpoint emulation (documented in EXPERIMENTS.md): all endpoints run OUR
engine/gateway code on a reduced mixtral-family model; the engine-quality
differences between the paper's endpoints are represented by their defining
*mechanisms*, not fake numbers:

  hf        static batching, sequential slots (transformers+FastAPI behavior)
            + per-step Python-loop overhead
  vllm      continuous batching + paged KV (vLLM's core) + Python scheduler
            overhead per iteration, FastAPI-style gateway
  scalellm  continuous batching + paged KV + zero host overhead + the
            optimized (binary/pooled) gateway

The gateway contrast (json+per-request connections+bounded sync workers vs
msgpack+pool+async) is REAL measured Python; only the connection handshake
latency constant is simulated (no physical network).
"""
from __future__ import annotations

import asyncio
from typing import Dict, Optional

import jax

from repro.configs import tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, Replica,
                        ReplicaRouter, RouterConfig, baseline_gateway_config,
                        scale_gateway_config, summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.metrics import BenchmarkSummary
from repro.data.workload import WorkloadSpec, sample_workload
from repro.models import build_model

ARCH = "mixtral-8x7b"          # the paper's evaluation model (reduced config)

ENGINE_STYLES = {
    "hf": dict(scheduler="static", max_slots=1, host_overhead_s=0.002,
               enable_prefix_cache=False),
    "vllm": dict(scheduler="max_utilization", max_slots=8, host_overhead_s=0.001,
                 enable_prefix_cache=False),
    "scalellm": dict(scheduler="max_utilization", max_slots=8, host_overhead_s=0.0),
}

_model_cache: Dict[str, tuple] = {}


def get_model(arch: str = ARCH):
    if arch not in _model_cache:
        cfg = tiny_config(arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        _model_cache[arch] = (cfg, model, params)
    return _model_cache[arch]


def build_replicas(style: str, n_replicas: int = 1, *, arch: str = ARCH,
                   max_slots: Optional[int] = None, klass: str = "default",
                   tracer=None, engine_overrides: Optional[dict] = None,
                   injector=None, step_watchdog_s: Optional[float] = None):
    cfg, model, params = get_model(arch)
    kw = dict(page_size=8, num_pages=256, max_seq=192, prefill_bucket=16,
              greedy=True, **ENGINE_STYLES[style])
    if max_slots is not None:
        kw["max_slots"] = max_slots
    if engine_overrides:
        kw.update(engine_overrides)
    rkw: dict = {"klass": klass, "injector": injector}
    if step_watchdog_s is not None:
        rkw["step_watchdog_s"] = step_watchdog_s
    return [Replica(f"{style}-{i}",
                    InferenceEngine(model, params, EngineConfig(**kw), tracer=tracer),
                    **rkw).start() for i in range(n_replicas)]


def run_endpoint(style: str, gateway: str, *, concurrency: int, n_requests: int,
                 n_replicas: int = 1, max_new: int = 10, timeout_s: float = 60.0,
                 policy: str = "least_loaded", seed: int = 0,
                 replicas=None) -> BenchmarkSummary:
    cfg, model, params = get_model()
    fleet = replicas or build_replicas(style, n_replicas)
    router = ReplicaRouter(fleet, RouterConfig(policy=policy))
    gw_cfg = scale_gateway_config() if gateway == "scale" else baseline_gateway_config()
    gw = Gateway(router, gw_cfg)
    prompts, _ = sample_workload(WorkloadSpec(n_requests=n_requests, vocab=cfg.vocab,
                                              scale=0.04, seed=seed))

    async def main():
        return await run_workload(gw, prompts, concurrency=concurrency,
                                  max_new_tokens=max_new, timeout_s=timeout_s)

    res = asyncio.run(main())
    merge_engine_timestamps(res.requests, gw)
    if replicas is None:
        for r in fleet:
            r.stop()
    return summarize(res.requests, res.t_start, res.t_end, concurrency,
                     timeout_s=timeout_s)


def warmup():
    """Compile the jitted prefill/decode once so benches measure serving."""
    run_endpoint("scalellm", "scale", concurrency=2, n_requests=2, max_new=4)


def row(name: str, us_per_call: float, **derived) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


def stamp() -> dict:
    """Provenance for persisted BENCH_*.json payloads: the git revision the
    numbers came from plus a UTC timestamp, so a perf trajectory across PRs
    can be reconstructed from the artifacts alone."""
    import datetime
    import os
    import subprocess
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    return {"git_rev": rev,
            "timestamp": datetime.datetime.now(datetime.timezone.utc)
                         .isoformat(timespec="seconds")}
