"""Paper Fig 6 + Exp2: latency decomposition across the {baseline, scale}
engine x gateway grid. Reproduces the paper's phenomenon: the optimized
engine makes the baseline gateway the bottleneck; swapping in the ScaleLLM
gateway moves the bottleneck back to the engine."""
from __future__ import annotations

from benchmarks.common import row, run_endpoint

GRID = [("vllm", "baseline"), ("vllm", "scale"),
        ("scalellm", "baseline"), ("scalellm", "scale")]


def run(quick: bool = True):
    rows = []
    concs = [4, 16] if quick else [4, 16, 64, 128]
    for style, gw in GRID:
        for c in concs:
            n = min(2 * c, 24 if quick else 20 * c)
            s = run_endpoint(style, gw, concurrency=c, n_requests=n, max_new=8)
            rows.append(row(
                f"fig6.{style}_engine+{gw}_gw.c{c}.gateway_latency",
                s.mean["gateway_latency"] * 1e6,
                engine_latency_us=s.mean["engine_latency"] * 1e6,
                avg_latency_us=s.mean["avg_latency"] * 1e6,
                bottleneck=("gateway" if s.mean["gateway_latency"] >
                            s.mean["engine_latency"] else "engine"),
            ))
    return rows
