"""Chaos serving bench: replay bursty traffic while a seeded fault schedule
injects replica crashes, step stalls, slow steps, transient submit errors,
and artificial KV page pressure (DESIGN.md §5). Produces
``BENCH_chaos.json`` with two scenarios:

  failover   3 replicas; one crashes mid-run, one stalls past the step
             watchdog. The router's health monitor must detect BOTH
             automatically (no manual ``handle_failure``) and resume the
             orphans mid-stream. Gated on availability, automatic failover
             for crash AND stall, failover latency, a fault-free twin whose
             greedy outputs bit-match the chaos run, and zero leaked KV
             pages at exit (dead replicas included).

  overload   1 replica behind a bounded admission queue: a burst over
             ``max_inflight`` exercises load shedding (terminal "shed"
             events, not hangs), tight per-request deadlines exercise
             deadline cancellation (which must free pages), and sustained
             overload arms the brown-out controller, which must recover by
             hysteresis once the burst drains.

Standalone smoke entry for CI:  ``python benchmarks/bench_chaos.py --smoke``.
"""
from __future__ import annotations

import asyncio
import json
import time

import numpy as np

from benchmarks.common import build_replicas, get_model, row, stamp
from repro.core import (FaultInjector, FaultPlan, Gateway, GatewayConfig,
                        MetricsSink, ReplicaRouter, RouterConfig, SLOConfig,
                        TimelineAggregator, Tracer)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.dashboard import render_dashboard, render_markdown
from repro.core.metrics import now
from repro.data.workload import WorkloadSpec, sample_workload

OUT_JSON = "BENCH_chaos.json"
OUT_HTML = "BENCH_chaos.html"
OUT_MD = "BENCH_chaos.md"

SEED = 1234


def _drain_and_leakcheck(fleet, injector=None):
    """Stop every replica (dead ones included) and assert the allocators
    leaked nothing: artificial holds released, zero slot-referenced pages,
    full invariant sweep. Returns total leaked pages (0 on success)."""
    if injector is not None:
        injector.release_holds([r.engine for r in fleet])
    leaked = 0
    for r in fleet:
        r.stop()
        r.engine.allocator.check_invariants()
        leaked += r.engine.allocator.live_pages
    return leaked


def _completed_ok(requests):
    return [r for r in requests if r.finished and r.error is None]


def _p99_ttft(requests):
    vals = [r.t4 - r.t0 for r in requests if r.t4 > 0 and r.t0 > 0]
    return float(np.percentile(vals, 99)) if vals else 0.0


# --------------------------------------------------------------- scenario A
def _run_failover(n_requests: int, max_new: int, *, chaos: bool,
                  window_s: float, timeout_s: float):
    """Open-loop run over 3 replicas; with ``chaos`` the seeded fault plan
    crashes replica 0, stalls replica 1, slows + pressures replica 2, and
    opens a transient submit-error window. Detection is fully automatic:
    the bench never calls handle_failure."""
    cfg, _, _ = get_model()
    span_s = 2.5
    watchdog_s = 1.5
    plan = FaultPlan(seed=SEED)
    injector = None
    if chaos:
        (plan.crash("scalellm-0", 0.9)
             .stall("scalellm-1", 1.2, 6.0)
             .slow("scalellm-2", 0.3, 0.6, factor=2.0)
             .kv_pressure("scalellm-2", 0.5, 1.0, pages=40)
             .submit_error(0.3, 0.25, prob=1.0))
        injector = FaultInjector(plan)
    tracer = Tracer(enabled=True)
    sink = MetricsSink()
    fleet = build_replicas("scalellm", 3, tracer=tracer, injector=injector,
                           step_watchdog_s=watchdog_s)
    # retry budget sized so a request arriving at the submit-error window's
    # open can always back off past its close (window 0.25 s; worst-case
    # jitter 0.5x => cumulative backoff exceeds it by attempt 7)
    router = ReplicaRouter(
        fleet, RouterConfig(policy="least_loaded", retry_budget=8,
                            retry_backoff_s=0.01, monitor_interval_s=0.03),
        sink=sink, tracer=tracer, injector=injector)
    gw = Gateway(router, GatewayConfig())
    prompts, _ = sample_workload(WorkloadSpec(
        n_requests=n_requests, vocab=cfg.vocab, scale=0.04, seed=SEED))
    # deterministic even spacing: guarantees arrivals inside every fault
    # window regardless of Poisson luck
    arrivals = np.linspace(0.0, span_s, n_requests)
    if injector is not None:
        injector.start()
    router.start_monitor()

    async def main():
        return await run_workload(gw, prompts, concurrency=32,
                                  max_new_tokens=max_new,
                                  timeout_s=timeout_s, arrivals=arrivals)

    t_bench0 = now()
    res = asyncio.run(main())
    router.stop_monitor()
    merge_engine_timestamps(res.requests, gw)
    agg = TimelineAggregator(window_s=window_s,
                             slo=SLOConfig(ttft_target_s=2.0, tbt_target_s=0.25))
    for rep in fleet:
        agg.add_steps(rep.step_records())
    for r in res.requests:
        if r.finished:
            agg.add_request(r)
    counters = sink.snapshot()
    for name in ("shed", "retries", "deadline_exceeded"):
        if counters.get(name):
            agg.add_event(name, t_bench0, int(counters[name]))
    for fe in router.failover_events:
        agg.add_failover(fe.t, fe.latency_s)
    leaked = _drain_and_leakcheck(fleet, injector)
    ok = _completed_ok(res.requests)
    return {
        "n_requests": n_requests,
        "completed": len(ok),
        "availability": len(ok) / n_requests,
        "p99_ttft_s": _p99_ttft(ok),
        "auto_failovers": router.auto_failovers,
        "manual_failovers": router.manual_failovers,
        "failover_reasons": sorted({fe.reason for fe in router.failover_events}),
        "failover_latency_max_s": max(
            (fe.latency_s for fe in router.failover_events), default=0.0),
        "failovers": [{"replica": fe.replica_id, "reason": fe.reason,
                       "latency_s": fe.latency_s, "n_requests": fe.n_requests}
                      for fe in router.failover_events],
        "retries": counters.get("retries", 0),
        "retry_exhausted": counters.get("retry_exhausted", 0),
        "injected": dict(injector.injected) if injector else {},
        "leaked_pages": leaked,
        "outputs": {r.req_id: list(r.generated) for r in ok},
    }, agg


# --------------------------------------------------------------- scenario B
def _run_overload(n_requests: int, *, window_s: float, timeout_s: float):
    """Single replica behind a bounded admission queue. Phase 1 fills the
    queue (two requests carry tight deadlines), phase 2 bursts over the
    bound and gets shed, phase 3 arrives after the drain. Sustained
    overload arms the brown-out; the bench then waits out the hysteresis
    and asserts recovery."""
    cfg, _, _ = get_model()
    max_inflight = 6
    gw_cfg = GatewayConfig(max_inflight=max_inflight, brownout_high=4,
                           brownout_low=1, brownout_sustain_s=0.05,
                           brownout_recover_s=0.4, brownout_max_new_tokens=4)
    tracer = Tracer(enabled=True)
    sink = MetricsSink()
    fleet = build_replicas("scalellm", 1, tracer=tracer)
    router = ReplicaRouter(fleet, RouterConfig(policy="least_loaded"),
                           sink=sink, tracer=tracer)
    gw = Gateway(router, gw_cfg)
    prompts, _ = sample_workload(WorkloadSpec(
        n_requests=n_requests, vocab=cfg.vocab, scale=0.04, seed=SEED + 1))
    n_admit = max_inflight
    n_late = 3
    n_burst = n_requests - n_admit - n_late
    arrivals = np.concatenate([
        np.zeros(n_admit),                          # fill the queue
        np.linspace(0.15, 0.7, n_burst),            # over the bound: shed
        np.full(n_late, 3.0),                       # after the drain
    ])
    extra_params = [None] * n_requests
    extra_params[0] = {"deadline_s": 0.25}          # expire mid-generation
    extra_params[1] = {"deadline_s": 0.25}

    async def main():
        return await run_workload(gw, prompts, concurrency=64,
                                  max_new_tokens=40, timeout_s=timeout_s,
                                  arrivals=arrivals,
                                  extra_params=extra_params)

    t_bench0 = now()
    res = asyncio.run(main())
    merge_engine_timestamps(res.requests, gw)
    activations = gw.brownout_activations
    # hysteresis recovery: traffic is gone; wait out the calm window
    deadline = time.monotonic() + 10 * gw_cfg.brownout_recover_s
    while gw.poll_brownout() and time.monotonic() < deadline:
        time.sleep(0.05)
    recovered = not gw.brownout
    agg = TimelineAggregator(window_s=window_s,
                             slo=SLOConfig(ttft_target_s=2.0, tbt_target_s=0.25))
    agg.add_steps(fleet[0].step_records())
    for r in res.requests:
        if r.finished and r.error is None:
            agg.add_request(r)
    counters = sink.snapshot()
    shed = sum(1 for r in res.requests if r.error == "shed")
    expired = sum(1 for r in res.requests if r.error == "deadline_exceeded")
    agg.add_event("shed", t_bench0, shed)
    agg.add_event("deadline_exceeded", t_bench0, expired)
    leaked = _drain_and_leakcheck(fleet)
    ok = _completed_ok(res.requests)
    return {
        "n_requests": n_requests,
        "max_inflight": max_inflight,
        "completed": len(ok),
        "shed": shed,
        "deadline_exceeded": expired,
        "engine_deadline_exceeded": fleet[0].engine.deadline_exceeded,
        "inflight_max": gw.inflight_max,
        "brownout_activations": activations,
        "brownout_recovered": recovered,
        "brownout_clamped": counters.get("brownout_clamped", 0),
        "p99_ttft_completed_s": _p99_ttft(ok),
        "leaked_pages": leaked,
    }, agg


def run(quick: bool = True, smoke: bool = False):
    if smoke:
        n_a, n_b, max_new, window_s, timeout = 16, 24, 8, 0.5, 60.0
    elif quick:
        n_a, n_b, max_new, window_s, timeout = 32, 32, 8, 0.5, 90.0
    else:
        n_a, n_b, max_new, window_s, timeout = 64, 60, 10, 1.0, 180.0

    chaos, agg = _run_failover(n_a, max_new, chaos=True,
                               window_s=window_s, timeout_s=timeout)
    clean, _ = _run_failover(n_a, max_new, chaos=False,
                             window_s=window_s, timeout_s=timeout)
    # greedy determinism across retry/failover/resume: every request that
    # completed in both runs must produce bit-identical tokens
    common = set(chaos["outputs"]) & set(clean["outputs"])
    mismatched = [rid for rid in sorted(common)
                  if chaos["outputs"][rid] != clean["outputs"][rid]]
    overload, _ = _run_overload(n_b, window_s=window_s, timeout_s=timeout)

    timeline = agg.timeline()
    summary = agg.summary()
    failover = {k: v for k, v in chaos.items() if k != "outputs"}
    failover.update({
        "greedy_identical": not mismatched,
        "greedy_compared": len(common),
        "greedy_mismatched": mismatched,
        "p99_ttft_fault_free_s": clean["p99_ttft_s"],
        "p99_ttft_degradation": (chaos["p99_ttft_s"] / clean["p99_ttft_s"]
                                 if clean["p99_ttft_s"] > 0 else 0.0),
    })
    rows = [
        row("chaos.availability", 0.0,
            availability=failover["availability"],
            completed=failover["completed"], total=n_a,
            leaked_pages=failover["leaked_pages"]),
        row("chaos.failover", 0.0,
            auto=failover["auto_failovers"], manual=failover["manual_failovers"],
            reasons=failover["failover_reasons"],
            latency_max_s=failover["failover_latency_max_s"],
            retries=failover["retries"]),
        row("chaos.determinism", 0.0,
            greedy_identical=failover["greedy_identical"],
            compared=failover["greedy_compared"],
            p99_ttft_degradation=failover["p99_ttft_degradation"]),
        row("chaos.overload", 0.0,
            shed=overload["shed"], deadline_exceeded=overload["deadline_exceeded"],
            inflight_max=overload["inflight_max"],
            brownout_activations=overload["brownout_activations"],
            brownout_recovered=overload["brownout_recovered"],
            p99_ttft_completed_s=overload["p99_ttft_completed_s"],
            leaked_pages=overload["leaked_pages"]),
    ]
    payload = {"bench": "chaos", "quick": quick, "smoke": smoke, **stamp(),
               "seed": SEED, "window_s": window_s,
               "fault_plan": [{"kind": "crash", "replica": "scalellm-0"},
                              {"kind": "stall", "replica": "scalellm-1"},
                              {"kind": "slow", "replica": "scalellm-2"},
                              {"kind": "kv_pressure", "replica": "scalellm-2"},
                              {"kind": "submit_error", "replica": None}],
               "failover": failover, "overload": overload,
               "summary": summary, "timeline": timeline, "rows": rows}
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    title = "ScaleLLM chaos run (injected crash/stall/slow/submit-error/KV pressure)"
    with open(OUT_HTML, "w") as f:
        f.write(render_dashboard(timeline, summary, title))
    with open(OUT_MD, "w") as f:
        f.write(render_markdown(timeline, summary, title))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny schedule for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    from benchmarks.common import warmup
    warmup()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        print(f"{r['name']}: {json.dumps(r['derived'], default=str)}")
    print(f"wrote {OUT_JSON}, {OUT_HTML}, {OUT_MD}")


if __name__ == "__main__":
    main()
