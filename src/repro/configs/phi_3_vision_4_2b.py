"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend STUB
(precomputed patch embeddings (B, 576, 1024)); the vision projector is real.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import LayerGroup, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    vision=VisionConfig(n_patches=576, d_patch=1024),
    layer_groups=(LayerGroup("A", 32),),
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
