"""gemma2-27b [dense] — local+global alternating attention, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.configs.base import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    scale_embedding=True,
    act="gelu",
    # alternating local (sliding-window) / global attention
    layer_groups=(LayerGroup("LG", 23),),
    source="arXiv:2408.00118; hf",
)
