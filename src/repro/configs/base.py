"""Configuration dataclasses for the repro framework.

Every architecture is described by a ``ModelConfig``; every workload cell by a
``ShapeConfig``. Configs are plain frozen dataclasses so they hash, print, and
serialize cleanly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_expert: int = 0              # expert FFN hidden size (fine-grained may differ from d_ff)
    capacity_factor: float = 1.25  # for the capacity-based (shardable) path
    router_jitter: float = 0.0
    # impl: "capacity" (einsum dispatch, shards via GSPMD; used for dry-run/train)
    #       "dropless" (sort + ragged gmm; exact, used by the serving engine)
    impl: str = "capacity"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (seamless). Frontend is a stub:
    the encoder consumes precomputed frame embeddings (B, frames, d_model)."""
    n_layers: int = 24
    cross_attn_memory: int = 1024  # encoder memory length seen by decode shapes


@dataclass(frozen=True)
class VisionConfig:
    """VLM frontend stub: precomputed patch embeddings (B, n_patches, d_patch)
    plus a real, sharded linear projector into the LM d_model."""
    n_patches: int = 576
    d_patch: int = 1024


@dataclass(frozen=True)
class LayerGroup:
    """A repeating block pattern. ``pattern`` is a string over:
      'A' full attention    'L' local (sliding-window) attention
      'G' global attention  'M' mamba2 (SSD)
    ``moe_mask`` marks which positions within the pattern use a MoE MLP
    (None = all dense, or a string of '0'/'1' with len == len(pattern)).
    Params for a group are stacked on a leading ``repeats`` dim and the body
    runs as a lax.scan over repeats.
    """
    pattern: str
    repeats: int
    moe_mask: Optional[str] = None

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    sliding_window: int = 0        # >0: window size for 'L' layers
    attn_softcap: float = 0.0      # gemma2: 50.0
    logit_softcap: float = 0.0     # gemma2: 30.0
    tie_embeddings: bool = False
    scale_embedding: bool = False  # gemma: x *= sqrt(d_model) after embed
    dense_d_ff: int = 0            # deepseek: first layer dense-FFN width
    act: str = "silu"              # silu (SwiGLU) | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    layer_groups: Tuple[LayerGroup, ...] = ()
    source: str = ""               # provenance tag from the assignment table

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if not self.layer_groups:
            pat = "M" if self.family == "ssm" else "A"
            object.__setattr__(
                self, "layer_groups", (LayerGroup(pattern=pat, repeats=self.n_layers),)
            )
        got = sum(g.n_layers for g in self.layer_groups)
        assert got == self.n_layers, f"{self.name}: layer_groups cover {got} != n_layers {self.n_layers}"

    # -- derived ------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return all(c == "M" for g in self.layer_groups for c in g.pattern)

    @property
    def has_subquadratic_path(self) -> bool:
        """True if the arch can serve 500k context: attention-free, hybrid, or
        sliding-window on a fraction of layers (bounded-cache local attention
        + mesh-sharded global cache)."""
        chars = [c for g in self.layer_groups for c in g.pattern]
        return any(c in ("M", "L") for c in chars)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped). long_500k is skipped for pure
    full-attention archs per the assignment; see DESIGN.md §4."""
    if shape.name == "long_500k" and not model.has_subquadratic_path:
        return False, "pure full-attention arch: 524k context not deployable (skip per DESIGN.md)"
    return True, ""
