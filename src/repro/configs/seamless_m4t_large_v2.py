"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal. The modality frontend
is a STUB: input_specs() supplies precomputed frame embeddings
(B, frames, d_model); the 24L encoder + 24L cross-attention decoder are real.
[arXiv:2308.11596; hf]"""
from repro.configs.base import EncoderConfig, LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                # decoder layers (encoder layers in EncoderConfig)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    encoder=EncoderConfig(n_layers=24, cross_attn_memory=1024),
    layer_groups=(LayerGroup("A", 24),),
    source="arXiv:2308.11596; hf",
)
