"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2 on
every other layer. Period-8 block: attention at position 4, mamba elsewhere.
[arXiv:2403.19887; hf]"""
from repro.configs.base import LayerGroup, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
    # 1:7 attn:mamba, MoE every other layer
    layer_groups=(LayerGroup("MMMMAMMM", 4, moe_mask="01010101"),),
    source="arXiv:2403.19887; hf",
)
