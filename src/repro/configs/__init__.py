"""Config registry: ``get_config("<arch-id>")`` and reduced smoke configs.

Arch ids are the assignment-table ids; ``mixtral-8x7b`` is the paper's own
evaluation model and is included in addition to the 10 assigned archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    DECODE_32K,
    EncoderConfig,
    LayerGroup,
    LONG_500K,
    ModelConfig,
    MoEConfig,
    PREFILL_32K,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    TRAIN_4K,
    VisionConfig,
    shape_applicable,
)

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma2-27b": "gemma2_27b",
    "yi-6b": "yi_6b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mixtral-8x7b": "mixtral_8x7b",
}

ASSIGNED_ARCHS: List[str] = [a for a in _MODULES if a != "mixtral-8x7b"]
ALL_ARCHS: List[str] = list(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    if name not in _cache:
        if name not in _MODULES:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
        import importlib

        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
        _cache[name] = mod.CONFIG
    return _cache[name]


def tiny_config(name: str, *, seq_len: int = 64) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests: same layer pattern
    shape (fewer repeats), tiny widths, tiny vocab. Exercises the identical
    code paths as the full config."""
    cfg = get_config(name)
    groups = tuple(
        dataclasses.replace(g, repeats=min(g.repeats, 2)) for g in cfg.layer_groups
    )
    n_layers = sum(g.n_layers for g in groups)
    moe = (
        dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_expert=64,
        )
        if cfg.moe
        else None
    )
    ssm = (
        dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=16)
        if cfg.ssm
        else None
    )
    enc = dataclasses.replace(cfg.encoder, n_layers=2, cross_attn_memory=32) if cfg.encoder else None
    vis = dataclasses.replace(cfg.vision, n_patches=8, d_patch=48) if cfg.vision else None
    return cfg.scaled(
        name=cfg.name + "-tiny",
        n_layers=n_layers,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        dense_d_ff=160 if cfg.dense_d_ff else 0,
        vocab=256,
        sliding_window=min(cfg.sliding_window, seq_len // 4) if cfg.sliding_window else 0,
        moe=moe,
        ssm=ssm,
        encoder=enc,
        vision=vis,
        layer_groups=groups,
    )
