"""phi3-mini-3.8b [dense] — RoPE SwiGLU, kv=32 => MHA. LongRoPE scaling is not
modeled (plain RoPE; noted in DESIGN.md §9). [arXiv:2404.14219; unverified]"""
from repro.configs.base import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    layer_groups=(LayerGroup("A", 32),),
    source="arXiv:2404.14219; unverified",
)
