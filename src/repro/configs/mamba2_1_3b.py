"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
d_inner = 2*d_model = 4096, head_dim 64 => 64 SSD heads, d_state 128.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import LayerGroup, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
    layer_groups=(LayerGroup("M", 48),),
    source="arXiv:2405.21060; unverified",
)
