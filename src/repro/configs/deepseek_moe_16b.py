"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts;
first layer dense (d_ff 10944). [arXiv:2401.06066; hf]"""
from repro.configs.base import LayerGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    dense_d_ff=10944,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2, d_expert=1408),
    # layer 0 dense MLP, remaining 27 MoE
    layer_groups=(LayerGroup("A", 1, moe_mask="0"), LayerGroup("A", 27, moe_mask="1")),
    source="arXiv:2401.06066; hf",
)
