"""mixtral-8x7b — the PAPER'S evaluation model (Mistral 8x7B, Jiang et al.
arXiv:2401.04088): 32L d_model=4096 32H GQA kv=8 d_ff=14336 vocab=32000,
8 experts top-2, sliding window 4096 (we model full attention + window flag
off, as Mixtral removed SWA for 8x7B). Used for the Exp4 TP x EP reproduction."""
from repro.configs.base import LayerGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336),
    layer_groups=(LayerGroup("A", 32, moe_mask="1"),),
    source="arXiv:2401.04088; paper's model",
)
