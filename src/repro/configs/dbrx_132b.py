"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base;
unverified]"""
from repro.configs.base import LayerGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
    layer_groups=(LayerGroup("A", 40, moe_mask="1"),),
    source="hf:databricks/dbrx-base; unverified",
)
