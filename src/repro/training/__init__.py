from repro.training.optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.training.train_step import TrainConfig, make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "TrainConfig", "make_train_step"]
