"""Training loop with checkpoint/restart (fault tolerance).

- resumes from the latest checkpoint automatically (params + opt state +
  data-pipeline step are all restored; batches are deterministic per step so
  a restart replays the exact stream position)
- async checkpointing off the step loop
- optional simulated crash step for the restart test
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data.pipeline import DataConfig, synthesize_batch
from repro.models import LM, RunCtx
from repro.training.train_step import TrainConfig, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    crash_at: Optional[int] = None    # simulate a node failure (tests)


class CrashForTest(Exception):
    pass


def train(model: LM, dcfg: DataConfig, tcfg: TrainConfig, rcfg: TrainerConfig,
          params=None, ctx: Optional[RunCtx] = None, seed: int = 0
          ) -> Dict[str, Any]:
    init_fn, step_fn = make_train_step(model, tcfg, ctx)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    start = 0
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))
    state = init_fn(params)
    if rcfg.ckpt_dir and latest_step(rcfg.ckpt_dir) is not None:
        (params, state), start = restore_checkpoint(rcfg.ckpt_dir, (params, state))
        params = jax.tree.map(jnp.asarray, params)
        state = jax.tree.map(jnp.asarray, state)

    ckpt = AsyncCheckpointer(rcfg.ckpt_dir) if rcfg.ckpt_dir else None
    losses: List[float] = []
    for step in range(start, rcfg.steps):
        if rcfg.crash_at is not None and step == rcfg.crash_at:
            if ckpt:
                ckpt.wait()
            raise CrashForTest(f"simulated failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in synthesize_batch(dcfg, step).items()}
        params, state, metrics = step_jit(params, state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if ckpt and (step + 1) % rcfg.ckpt_every == 0:
            ckpt.save(step + 1, (params, state))
    if ckpt:
        ckpt.save(rcfg.steps, (params, state))
        ckpt.wait()
    return {"params": params, "state": state, "losses": losses, "start": start}
