"""AdamW + cosine schedule, from scratch (no optax in this environment).

State is a pytree mirroring params (m, v moments) + a scalar step counter.
Moments inherit the params' sharding (same tree structure -> same
PartitionSpecs), which is what makes FSDP-style training memory work.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state). lr may be a traced scalar."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-20)
    scale = jnp.minimum(1.0, grad_clip / gnorm)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(step, *, peak_lr=3e-4, warmup=100, total=10_000, floor=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * step / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * t)))
    return jnp.where(step < warmup, warm, cos)
