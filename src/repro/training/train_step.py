"""Training step factory: loss -> grad -> (optional int8 grad compression
with error feedback) -> AdamW. Pure function of (params, opt_state, batch),
jit/pjit-able with sharded params (FSDP rules from distributed.sharding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.collectives import (compress_grads_with_feedback,
                                           decompress_grads, zeros_error_like)
from repro.models import LM, RunCtx
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule


@dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    aux_weight: float = 0.01
    grad_compression: bool = False     # int8 + error feedback
    remat: bool = True
    xent_chunk: int = 0                # >0: sequence-chunked cross-entropy
    microbatches: int = 1              # >1: gradient accumulation (memory)


def make_train_step(model: LM, tcfg: TrainConfig, ctx: Optional[RunCtx] = None
                    ) -> Tuple[Callable, Callable]:
    """Returns (init_fn(params) -> state, step_fn(params, state, batch) ->
    (params, state, metrics)). state = (AdamWState, error_feedback|None)."""
    ctx = ctx or RunCtx(mode="train", attn_backend="xla", moe_strategy="capacity",
                        remat=tcfg.remat)

    def init_fn(params):
        err = zeros_error_like(params) if tcfg.grad_compression else None
        return (adamw_init(params), err)

    def step_fn(params, state, batch):
        opt_state, err = state

        def loss_fn(p, b):
            loss, metrics = model.loss(p, b, ctx, aux_weight=tcfg.aux_weight,
                                       xent_chunk=tcfg.xent_chunk)
            return loss, metrics

        nm = tcfg.microbatches
        if nm <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # gradient accumulation: the activation working set shrinks nm x;
            # grads accumulate in a params-sized f32 buffer.
            mb = jax.tree.map(lambda x: x.reshape(nm, x.shape[0] // nm, *x.shape[1:]),
                              batch)

            def acc_step(carry, b_i):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b_i)
                g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32) / nm,
                                     g_acc, g)
                return (g_acc, l_acc + l / nm), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(acc_step, (g0, jnp.zeros((), jnp.float32)), mb)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        if tcfg.grad_compression:
            # compress -> (all-reduce happens on the quantized tree under
            # GSPMD data-parallel sharding) -> decompress
            qtree, err = compress_grads_with_feedback(grads, err)
            grads = decompress_grads(qtree)
        lr = cosine_schedule(opt_state.step + 1, peak_lr=tcfg.peak_lr,
                             warmup=tcfg.warmup, total=tcfg.total_steps)
        new_params, new_opt = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return new_params, (new_opt, err), metrics

    return init_fn, step_fn
