"""Serving-time quantization (paper §4.1 Model Quantization).

- weight-only int8 (per-output-channel absmax): halves weight HBM traffic vs
  bf16 — the quantization that pays on v5e (no fp8 MXU; fp8 is storage-only,
  see DESIGN.md). The Pallas w8a16 kernel consumes this format.
- fp8 (e4m3) storage cast for comparison.
- int8 KV-cache quantization (per-(token, head) absmax — KIVI-flavored
  asymmetric-lite) for the memory-bound decode regime.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantizedLinear(NamedTuple):
    q: jnp.ndarray         # int8, same shape as the original weight
    scale: jnp.ndarray     # f32, broadcastable over the quantized axis


def _quant_leaf(w, axis: int = -1) -> QuantizedLinear:
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return QuantizedLinear(q=q, scale=scale.astype(jnp.float32))


_QUANT_MIN_SIZE = 1 << 14   # only quantize big matmul weights


def quantize_params_int8(params) -> Any:
    """Quantize every large >=2D weight leaf to QuantizedLinear (int8 +
    per-channel scale); small leaves (norms, biases) stay as-is."""
    def one(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= _QUANT_MIN_SIZE \
                and jnp.issubdtype(w.dtype, jnp.floating):
            return _quant_leaf(w)
        return w
    return jax.tree.map(one, params)


def dequantize_tree(qparams, dtype=jnp.bfloat16):
    def one(leaf):
        return (leaf.q.astype(jnp.float32) * leaf.scale).astype(dtype) \
            if isinstance(leaf, QuantizedLinear) else leaf
    return jax.tree.map(one, qparams,
                        is_leaf=lambda x: isinstance(x, QuantizedLinear))


def fp8_cast_tree(params):
    """fp8 (e4m3) storage cast — on v5e this is storage-only (dequant to bf16
    before the MXU)."""
    def one(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
            return w.astype(jnp.float8_e4m3fn)
        return w
    return jax.tree.map(one, params)


# ---------------------------------------------------------------- KV cache
def kv_quantize(kv) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kv (..., hd) -> (int8 kv, f32 scale (..., 1)): per-(position, head)."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def kv_dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
