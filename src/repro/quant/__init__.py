from repro.quant.quantize import (QuantizedLinear, dequantize_tree, kv_quantize,
                                  kv_dequantize, quantize_params_int8)

__all__ = ["QuantizedLinear", "quantize_params_int8", "dequantize_tree",
           "kv_quantize", "kv_dequantize"]
