from repro.kernels.quant_matmul.ops import quantize_int8, w8a16_matmul
from repro.kernels.quant_matmul.ref import w8a16_matmul_reference

__all__ = ["w8a16_matmul", "w8a16_matmul_reference", "quantize_int8"]
