"""Oracle for w8a16 matmul: x (M,K) bf16/f32 @ int8 w (K,N) * scale (N,)."""
from __future__ import annotations

import jax.numpy as jnp


def w8a16_matmul_reference(x, w_q, scale):
    out = jnp.einsum(
        "mk,kn->mn", x.astype(jnp.float32), w_q.astype(jnp.float32)
    ) * scale[None, :].astype(jnp.float32)
    return out.astype(x.dtype)
