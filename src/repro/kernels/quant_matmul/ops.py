"""w8a16 matmul op with pallas/xla dispatch + quantize helper."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul import kernel as _kernel
from repro.kernels.quant_matmul.ref import w8a16_matmul_reference


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def quantize_int8(w, axis: int = 0):
    """Per-output-channel symmetric int8 quantization of a (K, N) weight.
    Returns (w_q int8, scale f32 per column)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return w_q, scale.reshape(-1).astype(jnp.float32)


def w8a16_matmul(x, w_q, scale, *, backend: str = "auto", interpret: bool | None = None, **blocks):
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return w8a16_matmul_reference(x, w_q, scale)
    if interpret is None:
        interpret = not _on_tpu()
    return _kernel.w8a16_matmul_pallas(x, w_q, scale, interpret=interpret, **blocks)
