"""Pallas TPU weight-only int8 matmul (w8a16).

v5e has no fp8 MXU (DESIGN.md §2), so the quantization that pays on this
target is int8 *storage*: HBM traffic for weights halves vs bf16 — decode is
memory-bound, so this moves the roofline memory term directly. The kernel
streams int8 weight tiles HBM->VMEM, dequantizes in-register, and runs the
MXU in bf16; per-output-channel scales are applied once on the final K step.

Grid = (m, n, k) with a f32 VMEM accumulator across the K sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _w8a16_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                 # (bm, bk)
    w = w_ref[...].astype(jnp.float32)                 # (bk, bn) dequant int8->f32
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] * s_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def w8a16_matmul_pallas(
    x,        # (M, K) bf16/f32
    w_q,      # (K, N) int8
    scale,    # (N,) f32 per-output-channel
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
):
    M, K = x.shape
    _, N = w_q.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_w8a16_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale.reshape(1, N))
