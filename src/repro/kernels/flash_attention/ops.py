"""Public attention op. Dispatch:

- ``backend="pallas"``   : the Pallas TPU kernel (interpret=True on CPU tests).
- ``backend="xla"``      : chunked online-softmax in pure jnp (double scan) —
                           identical math to the kernel, memory-bounded, lowers
                           on every backend. This is what the models trace for
                           the multi-pod dry-run, so the compiled HLO has
                           flash-style memory behaviour (no S x S buffer).
- ``backend="auto"``     : pallas on TPU else xla.

All paths are numerically validated against ``ref.mha_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _kernel


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def flash_attention(
    q,  # (B, Sq, H, D)
    k,  # (B, Skv, Hkv, D)
    v,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 512,
    block_kv: int = 1024,
    backend: str = "auto",
    interpret: bool | None = None,
    unroll: bool = False,
):
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    if scale is None:
        scale = D ** -0.5
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"

    if backend == "xla":
        return mha_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale,
            block_q=min(block_q, Sq), block_kv=min(block_kv, Skv),
            unroll=unroll,
        )

    if interpret is None:
        interpret = not _on_tpu()
    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Skv, 128))
    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_kv)
    vp = _pad_to(v, 1, block_kv)
    group = H // Hkv
    q3 = qp.transpose(0, 2, 1, 3).reshape(B * H, qp.shape[1], D)
    k3 = kp.transpose(0, 2, 1, 3).reshape(B * Hkv, kp.shape[1], D)
    v3 = vp.transpose(0, 2, 1, 3).reshape(B * Hkv, vp.shape[1], D)
    o3 = _kernel.flash_attention_bhsd(
        q3, k3, v3, kv_len=Skv, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, scale=scale, block_q=block_q, block_kv=block_kv,
        group=group, interpret=interpret,
    )
    o = o3.reshape(B, H, qp.shape[1], D).transpose(0, 2, 1, 3)
    return o[:, :Sq]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset", "scale",
                     "block_q", "block_kv", "unroll"),
)
def mha_chunked(
    q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
    scale=None, block_q=1024, block_kv=1024, unroll=False,
):
    """Flash attention in pure jnp: lax.map over q blocks, lax.scan over kv
    chunks with online-softmax carry. Peak temp = (B, H, block_q, block_kv).
    ``unroll=True`` replaces the loops with Python loops so XLA cost_analysis
    sees every tile (roofline cost lowering)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_kv)
    vp = _pad_to(v, 1, block_kv)
    Sqp, Skvp = qp.shape[1], kp.shape[1]
    nq, nkv = Sqp // block_q, Skvp // block_kv

    # (nq, B, bq, H, D) / (nkv, B, bkv, Hkv, D)
    qb = qp.reshape(B, nq, block_q, H, D).transpose(1, 0, 2, 3, 4)
    kb = kp.reshape(B, nkv, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nkv, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_block(args):
        qi, qblk = args  # qblk (B, bq, H, D)
        q_pos = qi * block_q + jnp.arange(block_q)[:, None] + q_offset  # (bq,1)

        def kv_step(carry, xs):
            m, l, acc = carry
            ki, kc, vc = xs  # (B, bkv, Hkv, D)
            k_pos = ki * block_kv + jnp.arange(block_kv)[None, :]  # (1,bkv)
            kc = jnp.repeat(kc, group, axis=2)
            vc = jnp.repeat(vc, group, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kc, preferred_element_type=jnp.float32) * scale
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            mask = k_pos < Skv
            if causal:
                mask = mask & (k_pos <= q_pos)
            if window > 0:
                mask = mask & (k_pos > q_pos - window)
            s = jnp.where(mask[None, None], s, _kernel.NEG_INF)
            m_cur = jnp.max(s, axis=-1)                     # (B,H,bq)
            m_new = jnp.maximum(m, m_cur)
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, block_q), _kernel.NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, D), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nkv):
                carry, _ = kv_step(carry, (jnp.asarray(j), kb[j], vb[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,bq,H,D)

    if unroll:
        outs = jnp.stack([q_block((jnp.asarray(i), qb[i])) for i in range(nq)])
    else:
        outs = jax.lax.map(q_block, (jnp.arange(nq), qb))  # (nq,B,bq,H,D)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sqp, H, D)
    return out[:, :Sq]
