"""Pallas TPU flash attention (blocked online-softmax), MaxText-style.

TARGET: TPU MXU/VMEM. Grid = (B*H, num_q_blocks, num_kv_blocks); the kv-block
axis is innermost so the f32 accumulators live in VMEM scratch across the kv
sweep. GQA is handled *in the index map* (kv head = q head // group) so the
grouped KV is never materialized in HBM. Causal / sliding-window blocks that
are wholly masked are skipped with ``pl.when`` (no MXU work), which is where
the 2x causal FLOP saving comes from on real hardware.

Validated on CPU with ``interpret=True`` against ``ref.mha_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    q_offset: int,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    kv_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + q_offset
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    # does this (q-block, kv-block) contain any live entry?
    q_max = iq * block_q + block_q - 1 + q_offset
    q_min = iq * block_q + q_offset
    k_min = ik * block_kv
    k_max = ik * block_kv + block_kv - 1
    needed = k_min <= jnp.minimum(q_max, kv_len - 1) if causal else k_min < kv_len
    if window > 0:
        needed = jnp.logical_and(needed, k_max > q_min - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, D)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                      # (block_q, 128) lanes equal
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)          # (block_q, 1)
        m_new = jnp.maximum(m_prev, m_cur)                  # broadcast lanes
        alpha = jnp.exp(m_prev - m_new)                     # (block_q, 128)
        p = jnp.exp(s - m_new[:, 0:1])                      # (block_q, block_kv)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha[:, 0:1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = l_scr[:, 0:1]
        out = jnp.where(denom > 0.0, acc_scr[...] / jnp.maximum(denom, 1e-30), 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kv_len", "causal", "window", "softcap", "q_offset", "scale",
        "block_q", "block_kv", "group", "interpret",
    ),
)
def flash_attention_bhsd(
    q3,  # (B*H,  Sq,  D)  -- Sq, Skv already padded to block multiples
    k3,  # (B*Hkv, Skv, D)
    v3,
    *,
    kv_len: int,  # true kv length before padding (<= Skv)
    causal: bool,
    window: int,
    softcap: float,
    q_offset: int,
    scale: float,
    block_q: int,
    block_kv: int,
    group: int,
    interpret: bool = False,
):
    BH, Sq, D = q3.shape
    _, Skv, _ = k3.shape
    nq = Sq // block_q
    nkv = Skv // block_kv

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        kv_len=kv_len,
    )
    grid = (BH, nq, nkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q3.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
