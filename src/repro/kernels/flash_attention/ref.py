"""Pure-jnp oracle for flash attention: naive masked attention.

Shapes:
  q: (B, Sq, H, D)    k, v: (B, Skv, Hkv, D)   with H % Hkv == 0 (GQA)
Returns (B, Sq, H, D).

``q_offset`` gives the absolute position of q[0] relative to k[0] (used for
decode / chunked prefill where q is a suffix of the kv stream).
``lengths`` (B,) masks kv positions >= length (paged/ragged decode).
"""
from __future__ import annotations

import jax.numpy as jnp


def mha_reference(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset=0,
    lengths=None,
    scale: float | None = None,
):
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    # expand kv heads to match q heads
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)

    qi = jnp.arange(Sq)[:, None] + q_offset  # absolute q positions (Sq, 1)
    kj = jnp.arange(Skv)[None, :]            # absolute kv positions (1, Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    mask = mask[None, None]                  # (1,1,Sq,Skv)
    if lengths is not None:
        mask &= (kj[None] < lengths[:, None, None])[:, None]

    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    # rows that are fully masked (can happen with lengths=0) produce zeros
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = jnp.where(denom > 0, probs / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)
