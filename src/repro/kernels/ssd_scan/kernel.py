"""Pallas TPU kernel for the Mamba2 SSD chunk scan (arXiv:2405.21060).

One grid step processes one (sequence, head) chunk: the within-chunk
quadratic term runs on the MXU (two (Q,Q)x(Q,P) matmuls), and the cross-chunk
state recurrence is carried in VMEM scratch across the chunk axis of the
grid — the same accumulate-over-inner-grid-axis idiom as the flash-attention
kernel. This is the TPU-native shape of SSD: instead of a separate
`associative_scan` pass over HBM, the state never leaves VMEM.

Grid = (B*H, num_chunks); chunk axis innermost (sequential on TPU).
Inputs are pre-chunked (B*H, nc, Q, ·) with `cum` = within-chunk inclusive
cumsum of dt*A (elementwise, computed outside).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    cum = cum_ref[0, 0].astype(jnp.float32)    # (Q, 1)  inclusive cumsum of dt*A
    Bm = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    # within-chunk quadratic term: Y_diag[i] = sum_{j<=i} (C_i.B_j) e^{cum_i-cum_j} dt_j x_j
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    decay = jnp.exp(cum - cum.T)                                   # e^{cum_i - cum_j}
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(ii >= jj, cb * decay * dt.T, 0.0)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)

    # inter-chunk term: Y_off[i] = (C_i e^{cum_i}) . state_prev
    state = state_scr[...]                                         # (N, P)
    y += jax.lax.dot_general(Cm * jnp.exp(cum), state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: state_new = e^{cum_Q} state + sum_j e^{cum_Q - cum_j} dt_j B_j (x) x_j
    total = jnp.exp(cum[chunk - 1: chunk])                         # (1, 1) e^{cum_Q}
    w = jnp.exp(cum[chunk - 1: chunk] - cum) * dt                  # (Q, 1)
    state_scr[...] = state * total + jax.lax.dot_general(
        Bm * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (N, P)

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xc,    # (BH, nc, Q, P)
    dtc,   # (BH, nc, Q, 1)   post-softplus dt
    cumc,  # (BH, nc, Q, 1)   within-chunk inclusive cumsum of dt*A
    bc,    # (BH, nc, Q, N)
    cc,    # (BH, nc, Q, N)
    *,
    chunk: int,
    interpret: bool = False,
):
    BH, nc, Q, P = xc.shape
    N = bc.shape[-1]
    assert Q == chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid = (BH, nc)
    def spec3(d):
        return pl.BlockSpec((1, 1, Q, d), lambda b, c: (b, c, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec3(P), spec3(1), spec3(1), spec3(N), spec3(N)],
        out_specs=spec3(P),
        out_shape=jax.ShapeDtypeStruct((BH, nc, Q, P), xc.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, cumc, bc, cc)
