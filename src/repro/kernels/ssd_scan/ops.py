"""Public SSD chunk-scan op: reshapes/chunk-prep + pallas/xla dispatch.
Same signature as models.mamba.ssd_chunked (minus init_state: the kernel owns
the state in VMEM; chunked-prefill continuation uses the jnp path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as _kernel
from repro.kernels.ssd_scan.ref import ssd_reference


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def ssd_scan(x, dt, A, B_, C, chunk: int, *, backend: str = "auto",
             interpret: bool | None = None):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative; B_/C (B,L,H,N).
    Returns y (B,L,H,P) — matches ssd_reference(...)[0]."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return ssd_reference(x, dt, A, B_, C, chunk)[0]
    if interpret is None:
        interpret = not _on_tpu()

    Bb, L, H, P = x.shape
    N = B_.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    def to_bh(a, d):   # (B,L,H,d) -> (B*H, nc, Q, d)
        return a.transpose(0, 2, 1, 3).reshape(Bb * H, nc, chunk, d)

    dtc = dt.transpose(0, 2, 1).reshape(Bb * H, nc, chunk, 1).astype(jnp.float32)
    dA = dtc * A.astype(jnp.float32)[None, :, None].repeat(Bb, 0).reshape(Bb * H, 1, 1, 1)
    cum = jnp.cumsum(dA, axis=2)
    y = _kernel.ssd_scan_pallas(
        to_bh(x, P), dtc, cum, to_bh(B_, N), to_bh(C, N),
        chunk=chunk, interpret=interpret)
    y = y.reshape(Bb, H, Lp, P).transpose(0, 2, 1, 3)
    return y[:, :L]
