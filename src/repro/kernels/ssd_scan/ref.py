"""Oracle for the SSD chunk-scan kernel: the pure-jnp chunked SSD from
repro.models.mamba (itself validated against a naive sequential recurrence in
tests/test_mamba.py)."""
from repro.models.mamba import ssd_chunked as ssd_reference  # noqa: F401
