from repro.kernels.paged_attention.ops import (chunked_prefill_attention,
                                               paged_attention)
from repro.kernels.paged_attention.ref import (chunked_prefill_reference,
                                               paged_attention_reference)

__all__ = ["paged_attention", "paged_attention_reference",
           "chunked_prefill_attention", "chunked_prefill_reference"]
