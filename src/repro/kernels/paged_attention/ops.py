"""Public paged attention ops (decode + chunked prefill) with pallas/xla
dispatch.

The xla path (gather via page_table indexing) is what the CPU serving engine
executes; the pallas path is the TPU target, validated in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as _kernel
from repro.kernels.paged_attention.ref import (chunked_prefill_reference,
                                               paged_attention_reference)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def paged_attention(
    q, k_pages, v_pages, page_table, lengths, *,
    scale: float | None = None, softcap: float = 0.0, window: int = 0,
    backend: str = "auto", interpret: bool | None = None,
):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return paged_attention_reference(
            q, k_pages, v_pages, page_table, lengths,
            scale=scale, softcap=softcap, window=window,
        )
    if interpret is None:
        interpret = not _on_tpu()
    return _kernel.paged_attention_pallas(
        q, k_pages, v_pages, page_table, lengths,
        scale=scale, softcap=softcap, window=window, interpret=interpret,
    )


def chunked_prefill_attention(
    q, k_pages, v_pages, page_table, lengths, q_positions, *,
    scale: float | None = None, softcap: float = 0.0, window: int = 0,
    backend: str = "auto", interpret: bool | None = None,
):
    """Chunked paged prefill: q (B, C, H, D) at absolute q_positions (B, C)
    attends causally over the pool (the chunk's own KV included).

    The pallas kernel assumes the positions of a row are contiguous
    (``q_positions[b, i] == q_positions[b, 0] + i`` — true for every
    engine-issued chunk); callers with non-affine positions (e.g. a VLM
    patch-prefix row) must use the xla path.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return chunked_prefill_reference(
            q, k_pages, v_pages, page_table, lengths, q_positions,
            scale=scale, softcap=softcap, window=window,
        )
    if interpret is None:
        interpret = not _on_tpu()
    starts = q_positions[:, 0].astype(jnp.int32)
    return _kernel.chunked_prefill_pallas(
        q, k_pages, v_pages, page_table, lengths, starts,
        scale=scale, softcap=softcap, window=window, interpret=interpret,
    )
