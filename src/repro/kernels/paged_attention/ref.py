"""Oracles for paged attention.

Decode (one query token per sequence):
  q:          (B, H, D)
  k_pages:    (P, page_size, Hkv, D)   global physical page pool
  v_pages:    (P, page_size, Hkv, D)
  page_table: (B, max_pages)    int32 physical page id per logical page
  lengths:    (B,)              valid kv entries per sequence (incl. current)

Chunked prefill (a chunk of S query tokens per sequence, causal against the
KV already resident in the pool — which includes the chunk's own KV, written
by the caller before attending):
  q:           (B, S, H, D)
  q_positions: (B, S) int32    absolute position of each query token
  lengths:     (B,)            total resident kv entries (incl. this chunk)
"""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_reference(
    q, k_pages, v_pages, page_table, lengths, *, scale=None, softcap: float = 0.0, window: int = 0
):
    B, H, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    k = k_pages[page_table].reshape(B, maxp * ps, Hkv, D)
    v = v_pages[page_table].reshape(B, maxp * ps, Hkv, D)
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    s = jnp.einsum("bhd,bkhd->bhk", q, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(maxp * ps)[None, :]
    mask = pos < lengths[:, None]
    if window > 0:
        mask &= pos > (lengths[:, None] - 1) - window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhk,bkhd->bhd", (p / denom).astype(jnp.float32), v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_prefill_reference(
    q, k_pages, v_pages, page_table, lengths, q_positions, *,
    scale=None, softcap: float = 0.0, window: int = 0,
):
    """Gather-based oracle for chunked paged prefill. Returns (B, S, H, D).

    Query token i of row b sits at absolute position q_positions[b, i] and
    attends causally to kv positions <= q_positions[b, i] (clipped to
    lengths[b]); rows where q_positions is past lengths produce zeros."""
    B, S, H, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    k = k_pages[page_table].reshape(B, maxp * ps, Hkv, D)
    v = v_pages[page_table].reshape(B, maxp * ps, Hkv, D)
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    s = jnp.einsum("bshd,bkhd->bhsk", q, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.arange(maxp * ps)[None, None, :]          # (1, 1, K)
    q_pos = q_positions[:, :, None]                        # (B, S, 1)
    mask = (kv_pos < lengths[:, None, None]) & (kv_pos <= q_pos)
    if window > 0:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[:, None], s, -1e30)                 # (B, H, S, K)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[:, None], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhsk,bkhd->bshd", (p / denom).astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)
