"""Pallas TPU paged-attention kernels: decode and chunked prefill.

TPU adaptation of PagedAttention (Kwon et al.): instead of CUDA
pointer-chasing into the page pool, the page table is a **scalar-prefetch
operand** and ``BlockSpec.index_map`` selects the physical page for each grid
step — the Mosaic pipeline turns that into scheduled HBM->VMEM DMAs, which is
the TPU-native form of paged KV gather (see DESIGN.md §2).

Decode: grid = (B, Hkv, max_pages); one query token per sequence; online
softmax accumulates in VMEM scratch over the page sweep; pages past a
sequence's length are skipped with ``pl.when`` (no DMA is wasted on them
either: their index map degrades to page 0 but the compute is skipped).

Chunked prefill: same grid, but each sequence contributes a *chunk* of
``chunk`` query tokens at absolute positions ``starts[b] + i``. The chunk's
own KV is already resident in the pool (the model writes it before
attending), so one page sweep serves both the history and the intra-chunk
causal triangle — there is no separate dense prefill cache and no
post-prefill scatter (see DESIGN.md §2). The (chunk, G) query axes are folded
into one VMEM row axis so GQA reuses each KV page DMA across the whole chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(
    # scalar prefetch
    pt_ref,      # (B, max_pages) int32
    len_ref,     # (B,) int32
    # inputs
    q_ref,       # (1, 1, G, D)
    k_ref,       # (1, page_size, 1, D)
    v_ref,       # (1, page_size, 1, D)
    # outputs
    o_ref,       # (1, 1, G, D)
    # scratch
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    softcap: float,
    window: int,
    page_size: int,
    max_pages: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    base = p * page_size
    needed = base < length
    if window > 0:
        needed = jnp.logical_and(needed, base + page_size - 1 > length - 1 - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (G, page_size)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        if window > 0:
            mask = jnp.logical_and(mask, pos > length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pr = jnp.exp(s - m_new[:, 0:1])
        pr = jnp.where(mask, pr, 0.0)
        l_scr[...] = alpha * l_prev + jnp.sum(pr, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha[:, 0:1] + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finalize():
        denom = l_scr[:, 0:1]
        out = jnp.where(denom > 0.0, acc_scr[...] / jnp.maximum(denom, 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "window", "interpret"),
)
def paged_attention_pallas(
    q,            # (B, H, D)
    k_pages,      # (P, page_size, Hkv, D)
    v_pages,
    page_table,   # (B, max_pages) int32
    lengths,      # (B,) int32
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
    interpret: bool = False,
):
    B, H, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    G = H // Hkv
    q4 = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(
        _paged_kernel, scale=scale, softcap=softcap, window=window,
        page_size=ps, max_pages=maxp,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q4, k_pages, v_pages)
    return out.reshape(B, H, D)


def _chunked_prefill_kernel(
    # scalar prefetch
    pt_ref,      # (B, max_pages) int32
    len_ref,     # (B,) int32     total resident kv (incl. this chunk)
    start_ref,   # (B,) int32     absolute position of the chunk's first token
    # inputs
    q_ref,       # (1, 1, C*G, D)
    k_ref,       # (1, page_size, 1, D)
    v_ref,       # (1, page_size, 1, D)
    # outputs
    o_ref,       # (1, 1, C*G, D)
    # scratch
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    softcap: float,
    window: int,
    page_size: int,
    max_pages: int,
    group: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    start = start_ref[b]
    base = p * page_size
    # pages at/after `length` hold no resident KV; causality never reaches
    # past the chunk end, and length == start + n_valid already covers that.
    needed = base < length
    if window > 0:
        needed = jnp.logical_and(needed, base + page_size - 1 > start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (C*G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (C*G, page_size)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        kv_pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        mask = jnp.logical_and(kv_pos < length, kv_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pr = jnp.exp(s - m_new[:, 0:1])
        pr = jnp.where(mask, pr, 0.0)
        l_scr[...] = alpha * l_prev + jnp.sum(pr, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha[:, 0:1] + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finalize():
        denom = l_scr[:, 0:1]
        out = jnp.where(denom > 0.0, acc_scr[...] / jnp.maximum(denom, 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "window", "interpret"),
)
def chunked_prefill_pallas(
    q,            # (B, C, H, D)  chunk of C query tokens per sequence
    k_pages,      # (P, page_size, Hkv, D)  pool, chunk KV already written
    v_pages,
    page_table,   # (B, max_pages) int32
    lengths,      # (B,) int32   resident kv entries incl. this chunk
    starts,       # (B,) int32   absolute position of q[:, 0]
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
    interpret: bool = False,
):
    B, C, H, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    G = H // Hkv
    # fold (chunk, G) into one row axis per kv head: row c*G + g <-> (c, g)
    q4 = q.reshape(B, C, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, C * G, D)

    kernel = functools.partial(
        _chunked_prefill_kernel, scale=scale, softcap=softcap, window=window,
        page_size=ps, max_pages=maxp, group=G,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, C * G, D), lambda b, h, p, pt, ln, st: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, p, pt, ln, st: (pt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, p, pt, ln, st: (pt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C * G, D), lambda b, h, p, pt, ln, st: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, C * G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, starts, q4, k_pages, v_pages)
    return out.reshape(B, Hkv, C, G, D).transpose(0, 2, 1, 3, 4).reshape(B, C, H, D)
