from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import expert_of_rows, gmm_reference

__all__ = ["gmm", "gmm_reference", "expert_of_rows"]
