"""Pallas TPU grouped matmul (megablox-style 'gmm') for dropless MoE.

Rows are sorted by expert and padded so every expert's rows occupy whole
``block_m`` tiles (the ops wrapper builds that layout with one scatter).
``tile_expert`` — the m-tile -> expert map — is a scalar-prefetch operand, so
``BlockSpec.index_map`` streams exactly one expert's weight tile per grid
step. Grid = (m_tiles, n_tiles); K is kept whole in VMEM (fine for the d_ff
sizes in the assigned archs: K*block_n*2B <= ~3MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(te_ref, x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)       # (bm, K)
    w = w_ref[0].astype(jnp.float32)         # (K, bn)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def gmm_pallas(
    x_padded,      # (Mp, K) tile-aligned rows, sorted by expert
    w,             # (E, K, N)
    tile_expert,   # (Mp // block_m,) int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    Mp, K = x_padded.shape
    E, _, N = w.shape
    assert Mp % block_m == 0 and N % block_n == 0
    grid = (Mp // block_m, N // block_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i, j, te: (i, 0)),
            pl.BlockSpec((1, K, block_n), lambda i, j, te: (te[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, te: (i, j)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, N), x_padded.dtype),
        interpret=interpret,
    )(tile_expert, x_padded, w)
