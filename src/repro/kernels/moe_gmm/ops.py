"""Grouped matmul op: builds the tile-aligned layout and dispatches.

``gmm(x, w, group_sizes)`` computes ``out[m] = x[m] @ w[expert_of(m)]`` for
rows sorted by expert. The wrapper scatters rows into a tile-aligned padded
buffer (each expert starts on a ``block_m`` boundary), runs the kernel (or an
einsum-select xla fallback for CPU), and gathers the real rows back. Static
worst-case padding: Mp = M + E*block_m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm import kernel as _kernel
from repro.kernels.moe_gmm.ref import expert_of_rows, gmm_reference


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "backend", "interpret"))
def gmm(
    x,             # (M, K) rows sorted by expert
    w,             # (E, K, N)
    group_sizes,   # (E,) int32, sum == M
    *,
    block_m: int = 128,
    block_n: int = 128,
    backend: str = "auto",
    interpret: bool | None = None,
):
    M, K = x.shape
    E, _, N = w.shape
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return gmm_reference(x, w, group_sizes)

    if interpret is None:
        interpret = not _on_tpu()
    bn = min(block_n, N)
    # --- tile-aligned scatter ------------------------------------------------
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    Mp = ((M + block_m - 1) // block_m + E) * block_m  # static worst case, tile-aligned
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_sizes)[:-1].astype(jnp.int32)])
    eid = expert_of_rows(group_sizes, M)               # (M,)
    ends = jnp.cumsum(group_sizes)
    row_in_group = jnp.arange(M) - jnp.concatenate([jnp.zeros((1,), ends.dtype), ends[:-1]])[eid]
    dst = starts[eid] + row_in_group                   # (M,)
    x_pad = jnp.zeros((Mp, K), x.dtype).at[dst].set(x)
    # m-tile -> expert map
    tile_ends = jnp.cumsum(padded_sizes) // block_m
    tile_expert = jnp.searchsorted(tile_ends, jnp.arange(Mp // block_m), side="right")
    tile_expert = jnp.minimum(tile_expert, E - 1).astype(jnp.int32)

    out_pad = _kernel.gmm_pallas(
        x_pad, w, tile_expert, block_m=block_m, block_n=bn, interpret=interpret
    )
    return out_pad[dst]
