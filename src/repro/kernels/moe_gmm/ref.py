"""Oracle for the grouped (ragged) expert matmul.

x:           (M, K)  rows sorted by expert id
w:           (E, K, N)
group_sizes: (E,)    sum == M
out[m] = x[m] @ w[expert_of(m)]
"""
from __future__ import annotations

import jax.numpy as jnp


def expert_of_rows(group_sizes, M):
    """(M,) expert id per row from group sizes (rows sorted by expert)."""
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(M), side="right")


def gmm_reference(x, w, group_sizes):
    M, K = x.shape
    E, _, N = w.shape
    eid = expert_of_rows(group_sizes, M)
    # O(E * M * K * N) dense oracle: compute every expert for every row, select.
    all_out = jnp.einsum("mk,ekn->emn", x.astype(jnp.float32), w.astype(jnp.float32))
    out = jnp.take_along_axis(all_out, eid[None, :, None], axis=0)[0]
    return out.astype(x.dtype)
