"""Production meshes. Functions, not module constants — importing this module
never touches jax device state.

Single pod : (16, 16)    axes ("data", "model")   = 256 chips (TPU v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

The "pod" axis carries data-parallel replica groups (requests are pod-local;
only gradient all-reduce / checkpoint distribution crosses pods — DCN, not
ICI). The Exp4 factored mesh exposes expert x tensor explicitly for the
paper's EP/TP sweep.
"""
from __future__ import annotations

import jax

try:                           # jax >= 0.4.35
    from jax.sharding import AxisType
except ImportError:            # older jax: meshes are Auto-typed already
    AxisType = None


def _mk(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_moe_mesh(ep: int, tp: int, *, chips: int = 256):
    """Factored Exp4 mesh: ("data", "expert", "tensor"). ep*tp must divide
    chips; the rest is data parallelism. e.g. (EP4, TP2) on 8 chips per the
    paper's DGX box, or EP x TP tiles of a 256-chip pod."""
    assert chips % (ep * tp) == 0, (chips, ep, tp)
    return _mk((chips // (ep * tp), ep, tp), ("data", "expert", "tensor"))


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests."""
    return _mk((data, model), ("data", "model"))
