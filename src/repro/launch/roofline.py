"""Roofline analysis (TPU v5e target) from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / 197e12       (bf16 MXU peak)
  memory term     = HLO_bytes_per_device / 819e9        (HBM bandwidth)
  collective term = collective_bytes_per_device / 50e9  (ICI per-link)

``cost_analysis()`` supplies FLOPs / bytes of the SPMD-partitioned
per-device program. Collective bytes are NOT in cost_analysis — we parse the
optimized HLO and sum the result-buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (async -start
forms included). Result sizes are per-device post-partitioning, matching the
per-device roofline denominators.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

V5E = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~1 effective link assumed)
    "hbm_capacity": 16e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective type (result-buffer sizes)."""
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)", rhs)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op.removesuffix("-start").removesuffix("-done")
        if base in _COLL_OPS and not op.endswith("-done"):
            out[base] += _shape_bytes(type_str)
            out["count"] += 1
    out["total"] = sum(out[o] for o in _COLL_OPS)
    return out


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant}


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, hw=V5E) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_dev / hw["peak_flops_bf16"],
        memory_s=bytes_per_dev / hw["hbm_bw"],
        collective_s=coll_bytes_per_dev / hw["ici_bw"],
    )


def local_bytes(shape_dtype_tree, spec_tree, mesh) -> int:
    """Per-device bytes of a sharded tree: leaf size / prod(assigned axes)."""
    import jax
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0
    leaves_v, treedef = jax.tree.flatten(shape_dtype_tree)
    leaves_p = treedef.flatten_up_to(spec_tree)
    for v, p in zip(leaves_v, leaves_p):
        n = int(np.prod(v.shape)) if v.shape else 1
        denom = 1
        spec = getattr(p, "spec", p)          # NamedSharding or PartitionSpec
        for entry in tuple(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= sizes.get(a, 1)
        total += (n // max(denom, 1)) * v.dtype.itemsize
    return total


def model_flops(cfg, shape) -> float:
    """Useful-FLOPs yardstick: 6·N·D for training (fwd+bwd), 2·N·D for
    serving, with N = active params for MoE. D = tokens processed."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch                 # decode: one token per sequence
    return 2.0 * n * tokens
