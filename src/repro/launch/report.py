"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load_cells(dryrun_dir: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        d["_file"] = os.path.basename(path)
        cells.append(d)
    return cells


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def _fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(cells: List[dict], *, moe: str = "tp") -> str:
    rows = ["| arch | shape | dominant | compute | memory (ub) | mem floor | collective | useful | roofline-frac | HBM args+temp |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("multi_pod") or d.get("seq_shard"):
            continue
        if d.get("moe") not in (None, moe):
            continue
        # baseline table: exclude hillclimb variants
        if (d.get("quant") not in (None, "none") or d.get("exp4")
                or d.get("xent_chunk") or (d.get("microbatches") or 1) > 1):
            continue
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | SKIP | - | - | - | - | - | - | {d['skipped'][:40]}... |")
            continue
        if "roofline" not in d:
            continue
        r = d["roofline"]
        mem = d.get("memory", {})
        hbm = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        rows.append(
            f"| {d['arch']} | {d['shape']} | **{r['dominant']}** "
            f"| {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r.get('memory_floor_s'))} | {_fmt_s(r['collective_s'])} "
            f"| {d.get('useful_ratio', 0):.2f} | {d.get('roofline_fraction', 0):.4f} "
            f"| {_fmt_b(hbm)} |")
    return "\n".join(rows)


def dryrun_table(cells: List[dict]) -> str:
    rows = ["| arch | shape | mesh | compiled | lower | compile | args/dev | temp/dev | collectives seen |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("seq_shard") or d.get("moe") == "ep":
            continue
        if (d.get("quant") not in (None, "none") or d.get("exp4")
                or d.get("xent_chunk") or (d.get("microbatches") or 1) > 1):
            continue
        mesh = "2x16x16" if d.get("multi_pod") else "16x16"
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | {mesh} | SKIP | - | - | - | - | {d['skipped'][:36]} |")
            continue
        mem = d.get("memory", {})
        coll = d.get("collectives", {})
        kinds = ",".join(k for k in ("all-gather", "all-reduce", "reduce-scatter",
                                     "all-to-all", "collective-permute")
                         if coll.get(k, 0) > 0) or "(cost probes skipped)" if not coll else \
            ",".join(k for k in ("all-gather", "all-reduce", "reduce-scatter",
                                 "all-to-all", "collective-permute") if coll.get(k, 0) > 0)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {mesh} | {'yes' if d.get('compiled_ok') else 'NO'} "
            f"| {d.get('lower_s', 0):.1f}s | {d.get('compile_s', 0):.1f}s "
            f"| {_fmt_b(mem.get('argument_bytes'))} | {_fmt_b(mem.get('temp_bytes'))} | {kinds} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--kind", default="roofline", choices=["roofline", "dryrun"])
    ap.add_argument("--moe", default="tp")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    if args.kind == "roofline":
        print(roofline_table(cells, moe=args.moe))
    else:
        print(dryrun_table(cells))


if __name__ == "__main__":
    main()
