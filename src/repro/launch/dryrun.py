import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init. Do not set this flag globally (smoke tests and
# benches should see 1 device).

__doc__ = """Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell, on the single-pod (16,16) and
multi-pod (2,16,16) production meshes:

    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(**input_specs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs / bytes for the roofline

Results are dumped as JSON under experiments/dryrun/ for the roofline table.
Run a single cell:    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
Run everything:       python -m repro.launch.dryrun --all   (subprocess per cell)
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (cache_partition_specs, make_rules, named,
                                        param_partition_specs, partition_spec,
                                        use_sharding)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (V5E, local_bytes, model_flops,
                                   parse_collective_bytes, roofline)
from repro.models import RunCtx, build_model
from repro.models.params import abstract_params, param_specs
from repro.training.optimizer import AdamWState, adamw_init
from repro.training.train_step import TrainConfig, make_train_step

DEC_START = 64          # enc-dec decoder segment length for prefill cells


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.vision is not None:
            S_text = S - cfg.vision.n_patches
            return {"tokens": _struct((B, S_text), jnp.int32),
                    "labels": _struct((B, S_text), jnp.int32),
                    "patches": _struct((B, cfg.vision.n_patches, cfg.vision.d_patch), jnp.bfloat16)}
        if cfg.encoder is not None:
            return {"tokens": _struct((B, S), jnp.int32),
                    "labels": _struct((B, S), jnp.int32),
                    "frames": _struct((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": _struct((B, S), jnp.int32),
                "labels": _struct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.vision is not None:
            S_text = S - cfg.vision.n_patches
            return {"tokens": _struct((B, S_text), jnp.int32),
                    "patches": _struct((B, cfg.vision.n_patches, cfg.vision.d_patch), jnp.bfloat16)}
        if cfg.encoder is not None:   # encode S frames, prefill a short decoder start
            return {"tokens": _struct((B, DEC_START), jnp.int32),
                    "frames": _struct((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": _struct((B, S), jnp.int32)}
    # decode: one new token against a seq_len cache
    return {"tokens": _struct((B, 1), jnp.int32),
            "positions": _struct((B,), jnp.int32)}


def _batch_shardings(batch_structs, mesh, rules):
    logical = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
               "patches": ("batch", None, None), "frames": ("batch", "seq", None),
               "positions": ("batch",)}
    return {k: named(mesh, partition_spec(v.shape, logical[k], mesh, rules))
            for k, v in batch_structs.items()}


def _quantize_abstract(params_t, p_sh):
    """Mirror quant.quantize_params_int8 over abstract params + shardings:
    big floating >=2D leaves become QuantizedLinear(q int8, scale f32) with
    the same spec on q and the last dim un-sharded on scale."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.quant.quantize import QuantizedLinear, _QUANT_MIN_SIZE

    def is_big(v):
        import numpy as np
        return (len(v.shape) >= 2 and int(np.prod(v.shape)) >= _QUANT_MIN_SIZE
                and jnp.issubdtype(v.dtype, jnp.floating))

    def walk(v, sh):
        if isinstance(v, dict):
            return ({k: walk(v[k], sh[k])[0] for k in v},
                    {k: walk(v[k], sh[k])[1] for k in v})
        if isinstance(v, list):
            pairs = [walk(a, b) for a, b in zip(v, sh)]
            return [p[0] for p in pairs], [p[1] for p in pairs]
        if is_big(v):
            scale_shape = tuple(v.shape[:-1]) + (1,)
            spec = sh.spec
            scale_spec = P(*(tuple(spec) + (None,) * (len(v.shape) - len(tuple(spec))))[:-1], None)
            qv = QuantizedLinear(
                q=jax.ShapeDtypeStruct(v.shape, jnp.int8),
                scale=jax.ShapeDtypeStruct(scale_shape, jnp.float32))
            qs = QuantizedLinear(q=sh, scale=NamedSharding(sh.mesh, scale_spec))
            return qv, qs
        return v, sh

    return walk(params_t, p_sh)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *, moe_mode: str = "tp",
               seq_shard: bool = False, cost_mode: bool = False,
               block_div: int = 4, quant: str = "none",
               xent_chunk: int = 0, microbatches: int = 1):
    """Build (lowered, meta) for one cell. ``cost_mode`` unrolls layers and
    attention tiles so XLA cost_analysis counts every iteration (see the
    affine calibration in run_cell)."""
    multi_pod = "pod" in mesh.axis_names
    factored = "tensor" in mesh.axis_names           # Exp4 mesh (data,expert,tensor)
    tensor_axis = "tensor" if factored else "model"
    expert_axis = "expert" if factored else None
    mode = "train" if shape.kind == "train" else "serve"
    rules = make_rules(mode, moe=moe_mode, multi_pod=multi_pod,
                       seq_shard=seq_shard, tensor_axis=tensor_axis,
                       expert_axis=expert_axis)
    strategy = ({"tp": "tp_shardmap", "ep": "ep_shardmap"}[moe_mode]
                if cfg.moe is not None else "capacity")
    model = build_model(cfg)
    specs = param_specs(cfg)
    blk = max(shape.seq_len // block_div, 1024) if cost_mode else 1024
    knobs = dict(scan_layers=not cost_mode, attn_unroll=cost_mode,
                 block_q=blk, block_kv=blk,
                 ep_axis=expert_axis or "data", tp_axis=tensor_axis,
                 quant="a2a_int8" if quant == "a2a_int8" else "none")

    if shape.kind == "train":
        ctx = RunCtx(mode="train", mesh=mesh, attn_backend="xla",
                     moe_strategy=strategy, remat=True, **knobs)
        _, step_fn = make_train_step(
            model, TrainConfig(remat=True, xent_chunk=xent_chunk,
                               microbatches=microbatches), ctx)
        params_t = abstract_params(cfg, jnp.float32)
        opt_t = jax.eval_shape(adamw_init, params_t)
        state_t = (opt_t, None)
        batch_t = input_specs(cfg, shape)

        p_sh = jax.tree.map(lambda s: named(mesh, s),
                            param_partition_specs(specs, mesh, rules))
        opt_sh = AdamWState(step=named(mesh, partition_spec((), (), mesh, rules)),
                            m=p_sh, v=p_sh)
        in_sh = (p_sh, (opt_sh, None), _batch_shardings(batch_t, mesh, rules))

        def fn(params, state, batch):
            return step_fn(params, state, batch)

        args = (params_t, state_t, batch_t)
        donate = (0, 1)          # params + opt state update in place
    else:
        ctx = RunCtx(mode=shape.kind, mesh=mesh, attn_backend="xla",
                     moe_strategy=strategy, **knobs)
        params_t = abstract_params(cfg, jnp.bfloat16)
        p_sh = jax.tree.map(lambda s: named(mesh, s),
                            param_partition_specs(specs, mesh, rules))
        batch_t = input_specs(cfg, shape)
        b_sh = _batch_shardings(batch_t, mesh, rules)
        mem_len = cfg.encoder.cross_attn_memory if cfg.encoder is not None else 0

        if shape.kind == "prefill":
            cache_t = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         jnp.bfloat16, kind="dense",
                                         memory_len=shape.seq_len if cfg.encoder else 0))
            c_sh = jax.tree.map(lambda s: named(mesh, s),
                                cache_partition_specs(cache_t, mesh, rules))

            def fn(params, batch, cache):
                return model.prefill(params, batch, cache, ctx)

            args = (params_t, batch_t, cache_t)
            in_sh = (p_sh, b_sh, c_sh)
            donate = (2,)        # cache filled in place
        else:
            cache_t = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         jnp.bfloat16, kind="dense",
                                         memory_len=mem_len))
            c_sh = jax.tree.map(lambda s: named(mesh, s),
                                cache_partition_specs(cache_t, mesh, rules))
            positions = batch_t.pop("positions")
            tokens = batch_t["tokens"]

            if quant == "int8":
                # the paper's weight-only quantization: int8 weights in HBM,
                # dequantized in-register before each matmul (w8a16)
                from repro.quant import dequantize_tree
                params_t, p_sh = _quantize_abstract(params_t, p_sh)

                def fn(params, tokens, cache, positions):
                    deq = dequantize_tree(params, jnp.bfloat16)
                    return model.decode_step(deq, tokens, cache, positions, ctx)
            else:
                def fn(params, tokens, cache, positions):
                    return model.decode_step(params, tokens, cache, positions, ctx)

            args = (params_t, tokens, cache_t, positions)
            in_sh = (p_sh, b_sh["tokens"], c_sh,
                     named(mesh, partition_spec((shape.global_batch,), ("batch",), mesh, rules)))
            donate = (2,)        # cache updated in place

    with mesh:
        with use_sharding(mesh, rules):
            t0 = time.time()
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
    sizes = {"params_local_bytes": local_bytes(params_t, p_sh, mesh)}
    if shape.kind != "train":
        sizes["cache_local_bytes"] = local_bytes(cache_t, c_sh, mesh)
    return lowered, t_lower, sizes


def _compile_costs(lowered) -> Dict[str, float]:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = parse_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{k}": float(v) for k, v in coll.items()}}


def _variant(cfg: ModelConfig, repeats: List[int], enc_layers: Optional[int]) -> ModelConfig:
    groups = tuple(dataclasses.replace(g, repeats=r)
                   for g, r in zip(cfg.layer_groups, repeats))
    n_layers = sum(g.n_layers for g in groups)
    enc = (dataclasses.replace(cfg.encoder, n_layers=enc_layers)
           if cfg.encoder is not None else None)
    return cfg.scaled(n_layers=n_layers, layer_groups=groups, encoder=enc)


def calibrated_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, moe_mode: str,
                     seq_shard: bool, block_div: int = 4,
                     quant: str = "none", xent_chunk: int = 0,
                     microbatches: int = 1) -> Dict[str, Any]:
    """XLA cost_analysis counts loop bodies once, so we measure UNROLLED
    probe variants at small repeat counts and solve the affine model
    cost = base + sum_i repeats_i * c_i  (exact: cost is affine in repeats).
    """
    G = len(cfg.layer_groups)
    has_enc = cfg.encoder is not None
    base_rep = [1] * G
    base_enc = 1 if has_enc else None
    probes = [(base_rep, base_enc)]
    for i in range(G):
        rep = list(base_rep)
        rep[i] = 2
        probes.append((rep, base_enc))
    if has_enc:
        probes.append((base_rep, 2))

    costs = []
    for rep, enc in probes:
        vcfg = _variant(cfg, rep, enc)
        lowered, _, _ = build_cell(vcfg, shape, mesh, moe_mode=moe_mode,
                                   seq_shard=seq_shard, cost_mode=True,
                                   block_div=block_div, quant=quant,
                                   xent_chunk=xent_chunk, microbatches=microbatches)
        costs.append(_compile_costs(lowered))

    keys = costs[0].keys()
    coeffs = [{k: costs[1 + i][k] - costs[0][k] for k in keys} for i in range(G)]
    enc_coeff = ({k: costs[1 + G][k] - costs[0][k] for k in keys} if has_enc else None)
    base = {k: costs[0][k] - sum(c[k] for c in coeffs)
            - (enc_coeff[k] if enc_coeff else 0.0) for k in keys}
    full = {}
    for k in keys:
        v = base[k] + sum(cfg.layer_groups[i].repeats * coeffs[i][k] for i in range(G))
        if enc_coeff:
            v += cfg.encoder.n_layers * enc_coeff[k]
        full[k] = v
    return {"full": full, "base": base,
            "per_group": coeffs, "encoder_coeff": enc_coeff,
            "n_probes": len(probes)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, moe_mode: str = "tp",
             seq_shard: bool = False, skip_cost: bool = False,
             quant: str = "none", exp4: Optional[str] = None,
             xent_chunk: int = 0, microbatches: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    meta: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "multi_pod": multi_pod, "mode": shape.kind,
                            "moe": moe_mode if cfg.moe is not None else None,
                            "seq_shard": seq_shard, "quant": quant,
                            "exp4": exp4, "xent_chunk": xent_chunk,
                            "microbatches": microbatches}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        meta["skipped"] = why
        return meta
    if exp4:
        from repro.launch.mesh import make_moe_mesh
        ep, tp = (int(x) for x in exp4.split("x"))
        mesh = make_moe_mesh(ep, tp, chips=256)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    meta["mesh"] = list(mesh.devices.shape)

    # ---- 1) production (scanned) lowering: the compile + memory proof ----
    lowered, t_lower, sizes = build_cell(cfg, shape, mesh, moe_mode=moe_mode,
                                         seq_shard=seq_shard, cost_mode=False,
                                         quant=quant, xent_chunk=xent_chunk,
                                         microbatches=microbatches)
    meta["lower_s"] = t_lower
    meta["sizes"] = sizes
    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = time.time() - t0
    mem = compiled.memory_analysis()
    meta["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    }
    meta["compiled_ok"] = True

    if skip_cost:
        return meta

    # ---- 2) affine-calibrated costs (unrolled probes) ----
    cal = calibrated_costs(cfg, shape, mesh, moe_mode=moe_mode, seq_shard=seq_shard,
                           quant=quant, xent_chunk=xent_chunk,
                           microbatches=microbatches)
    full = cal["full"]
    if microbatches > 1:
        # the gradient-accumulation lax.scan body is counted once by XLA's
        # cost analysis (same pathology the layer calibration fixes) — scale
        # by the trip count
        full = {k: v * microbatches for k, v in full.items()}
    meta["cost"] = {"flops": full["flops"], "bytes_accessed": full["bytes"],
                    "n_probes": cal["n_probes"]}
    meta["collectives"] = {k.removeprefix("coll_"): v for k, v in full.items()
                           if k.startswith("coll_")}

    # ---- 3) roofline ----
    terms = roofline(full["flops"], full["bytes"], full["coll_total"])
    meta["roofline"] = terms.as_dict()
    # memory floor: weights read once + cache streamed once — the fused-TPU
    # lower bound; XLA's bytes_accessed counts unfused copies and is the
    # upper bound. Real HBM time lies between.
    floor_bytes = sizes["params_local_bytes"] + sizes.get("cache_local_bytes", 0)
    meta["roofline"]["memory_floor_s"] = floor_bytes / V5E["hbm_bw"]
    mf = model_flops(cfg, shape)
    meta["model_flops_global"] = mf
    meta["model_flops_per_dev"] = mf / n_dev
    meta["useful_ratio"] = (mf / n_dev) / full["flops"] if full["flops"] else 0.0
    ideal_s = (mf / n_dev) / 197e12
    meta["roofline_fraction"] = ideal_s / terms.bound_s if terms.bound_s > 0 else 0.0
    return meta


ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=ALL_SHAPES + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "pod"])
    ap.add_argument("--moe", default="tp", choices=["tp", "ep"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell via subprocesses")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-cost", action="store_true",
                    help="compile + memory proof only (no cost probes)")
    ap.add_argument("--quant", default="none", choices=["none", "int8", "a2a_int8"],
                    help="int8 weight-only serving quant, or int8-compressed "
                         "MoE all-to-all dispatch (decode cells)")
    ap.add_argument("--exp4", default=None,
                    help="factored Exp4 mesh 'EPxTP' e.g. 4x4 (256 chips)")
    ap.add_argument("--xent-chunk", type=int, default=0,
                    help=">0: sequence-chunked cross-entropy (train cells)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help=">1: gradient accumulation (train cells)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        failures = []
        for mesh_kind in ("single", "pod"):
            for arch in ALL_ARCHS:
                for shape in ALL_SHAPES:
                    tag = f"{arch}__{shape}__{mesh_kind}__{args.moe}"
                    out_file = os.path.join(args.out, tag + ".json")
                    if os.path.exists(out_file):
                        print(f"[skip] {tag} (cached)")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                           "--moe", args.moe, "--out", args.out]
                    if mesh_kind == "pod":
                        # multi-pod pass proves the pod axis shards; the
                        # roofline table is single-pod only (spec)
                        cmd.append("--skip-cost")
                    print(f"[run ] {tag}", flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append(tag)
                        print(f"[FAIL] {tag}\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    res = run_cell(args.arch, args.shape, multi_pod=(args.mesh == "pod"),
                   moe_mode=args.moe, seq_shard=args.seq_shard,
                   skip_cost=args.skip_cost, quant=args.quant,
                   exp4=args.exp4, xent_chunk=args.xent_chunk,
                   microbatches=args.microbatches)
    tag = f"{args.arch}__{args.shape}__{args.mesh}__{args.moe}"
    if args.seq_shard:
        tag += "__seqshard"
    if args.quant != "none":
        tag += f"__{args.quant}"
    if args.exp4:
        tag += f"__exp4_{args.exp4}"
    if args.xent_chunk:
        tag += f"__xc{args.xent_chunk}"
    if args.microbatches > 1:
        tag += f"__mb{args.microbatches}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps(res, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
