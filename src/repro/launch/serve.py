"""Serving launcher: build a replica fleet + gateway for an --arch config and
drive a synthetic OpenOrca-like workload against it (real CPU execution with
the reduced config; the full config is exercised via the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --replicas 2 --concurrency 8 --requests 32 --gateway scale
"""
from __future__ import annotations

import argparse
import asyncio
import json

import jax

from repro.configs import get_config, tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, Replica,
                        ReplicaRouter, RouterConfig, baseline_gateway_config,
                        scale_gateway_config, summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.data.workload import WorkloadSpec, sample_workload
from repro.models import build_model


def build_fleet(arch: str, n_replicas: int, *, engine_kwargs=None, tiny: bool = True,
                klass: str = "default", seed: int = 0):
    cfg = tiny_config(arch) if tiny else get_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    kw = dict(max_slots=8, page_size=16, num_pages=256, max_seq=256,
              prefill_bucket=32, greedy=False)
    kw.update(engine_kwargs or {})
    replicas = []
    for i in range(n_replicas):
        eng = InferenceEngine(model, params, EngineConfig(**kw))
        replicas.append(Replica(f"{arch}-r{i}", eng, klass=klass).start())
    return cfg, replicas


async def serve_and_measure(arch: str, *, replicas: int, concurrency: int,
                            n_requests: int, gateway_kind: str, policy: str,
                            max_new: int = 24, seed: int = 0):
    cfg, fleet = build_fleet(arch, replicas, seed=seed)
    router = ReplicaRouter(fleet, RouterConfig(policy=policy))
    gw_cfg = scale_gateway_config() if gateway_kind == "scale" else baseline_gateway_config()
    gw = Gateway(router, gw_cfg)
    prompts, _ = sample_workload(WorkloadSpec(n_requests=n_requests, vocab=cfg.vocab,
                                              scale=0.05, seed=seed))
    res = await run_workload(gw, prompts, concurrency=concurrency,
                             max_new_tokens=max_new)
    merge_engine_timestamps(res.requests, gw)
    summary = summarize(res.requests, res.t_start, res.t_end, concurrency)
    for r in fleet:
        r.stop()
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--gateway", default="scale", choices=["scale", "baseline"])
    ap.add_argument("--policy", default="least_loaded",
                    choices=["round_robin", "least_loaded", "dynamic"])
    args = ap.parse_args()
    s = asyncio.run(serve_and_measure(
        args.arch, replicas=args.replicas, concurrency=args.concurrency,
        n_requests=args.requests, gateway_kind=args.gateway, policy=args.policy))
    print(json.dumps({
        "arch": args.arch, "gateway": args.gateway, "policy": args.policy,
        "concurrency": s.concurrency, "throughput_tok_s": s.throughput_tok_s,
        "mean": s.mean, "p99": s.p99, "timeout_frac": s.timeout_frac,
    }, indent=1))


if __name__ == "__main__":
    main()
