"""Training launcher (CPU-runnable with reduced configs; the full configs are
exercised by the dry-run's train cells).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 100 \
      --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json

from repro.configs import tiny_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.training.train_step import TrainConfig
from repro.training.trainer import TrainerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = tiny_config(args.arch)
    model = build_model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)
    tcfg = TrainConfig(peak_lr=args.lr, warmup=10, total_steps=args.steps,
                       grad_compression=args.grad_compression)
    out = train(model, dcfg, tcfg,
                TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every))
    print(json.dumps({
        "arch": args.arch, "resumed_from": out["start"],
        "first_loss": out["losses"][0] if out["losses"] else None,
        "last_loss": out["losses"][-1] if out["losses"] else None,
        "steps": args.steps,
    }, indent=1))


if __name__ == "__main__":
    main()
