"""Wire serialization — the paper's HTTP/1.1+JSON vs gRPC+protobuf contrast.

``JsonVerbose``  : stdlib json over an OpenAI-chat-completion-chunk style
                   envelope — what a FastAPI gateway streams (baseline).
``BinaryCompact``: msgpack over positional tuples — the protobuf stand-in the
                   ScaleLLM gateway uses (compact framing, C-speed codec).

Both are REAL codecs measured end-to-end; bytes-on-wire and encode/decode CPU
are genuine, the network itself is a latency model (gateway.py).
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, Tuple

import msgpack


class JsonVerbose:
    name = "json-http1"

    @staticmethod
    def encode_request(req_id: str, tokens, params: Dict[str, Any]) -> bytes:
        d = {
            "id": req_id,
            "object": "chat.completion.request",
            "model": params.get("model", "repro"),
            "messages": [{"role": "user", "content": " ".join(map(str, tokens))}],
            "prompt_tokens": [int(t) for t in tokens],
            "temperature": params.get("temperature", 0.5),
            "top_p": params.get("top_p", 0.7),
            "max_tokens": params.get("max_new_tokens", 64),
            "stream": True,
        }
        if params.get("greedy"):
            d["greedy"] = True
        if params.get("deadline_s") is not None:
            d["deadline_s"] = float(params["deadline_s"])
        return json.dumps(d).encode()

    @staticmethod
    def decode_request(data: bytes) -> Tuple[str, list, Dict[str, Any]]:
        d = json.loads(data)
        params = dict(d)
        params["max_new_tokens"] = d.get("max_tokens", 64)
        return d["id"], d["prompt_tokens"], params

    @staticmethod
    def encode_token(req_id: str, token: int, index: int, finished: bool) -> bytes:
        return json.dumps({
            "id": req_id,
            "object": "chat.completion.chunk",
            "created": int(time.time()),
            "model": "repro",
            "choices": [{
                "index": 0,
                "delta": {"role": "assistant", "content": f"<tok:{token}>"},
                "token_id": int(token),
                "token_index": int(index),
                "finish_reason": "stop" if finished else None,
            }],
        }).encode()

    @staticmethod
    def decode_token(data: bytes) -> Tuple[str, int, int, bool]:
        d = json.loads(data)
        c = d["choices"][0]
        return d["id"], c["token_id"], c["token_index"], c["finish_reason"] is not None


class BinaryCompact:
    name = "msgpack-grpc"

    @staticmethod
    def encode_request(req_id: str, tokens, params: Dict[str, Any]) -> bytes:
        return msgpack.packb((req_id, [int(t) for t in tokens],
                              params.get("temperature", 0.5),
                              params.get("top_p", 0.7),
                              params.get("max_new_tokens", 64),
                              bool(params.get("greedy", False)),
                              params.get("deadline_s")))

    @staticmethod
    def decode_request(data: bytes) -> Tuple[str, list, Dict[str, Any]]:
        parts = msgpack.unpackb(data)
        req_id, tokens, temp, top_p, mnt = parts[:5]
        params: Dict[str, Any] = {"temperature": temp, "top_p": top_p,
                                  "max_new_tokens": mnt}
        # trailing fields are optional: old 5-tuple frames still decode
        if len(parts) > 5 and parts[5]:
            params["greedy"] = True
        if len(parts) > 6 and parts[6] is not None:
            params["deadline_s"] = parts[6]
        return req_id, tokens, params

    @staticmethod
    def encode_token(req_id: str, token: int, index: int, finished: bool) -> bytes:
        return msgpack.packb((req_id, int(token), int(index), finished))

    @staticmethod
    def decode_token(data: bytes) -> Tuple[str, int, int, bool]:
        return tuple(msgpack.unpackb(data))  # type: ignore[return-value]


CODECS = {"json": JsonVerbose, "binary": BinaryCompact}
