"""Safety module: authentication, rate limiting, content filtering
(paper §1: "inference control" + Figure 1's Safety Module)."""
from __future__ import annotations

import hashlib
import hmac
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Set


class AuthError(Exception):
    pass


class RateLimited(Exception):
    pass


class ContentBlocked(Exception):
    pass


@dataclass
class Authenticator:
    """HMAC-signed API keys: token = user_id + ":" + hex(hmac(secret, user_id))."""
    secret: bytes = b"repro-secret"

    def issue(self, user_id: str) -> str:
        sig = hmac.new(self.secret, user_id.encode(), hashlib.sha256).hexdigest()
        return f"{user_id}:{sig}"

    def verify(self, token: str) -> str:
        try:
            user_id, sig = token.split(":", 1)
        except ValueError:
            raise AuthError("malformed token")
        expect = hmac.new(self.secret, user_id.encode(), hashlib.sha256).hexdigest()
        if not hmac.compare_digest(sig, expect):
            raise AuthError("bad signature")
        return user_id


@dataclass
class TokenBucket:
    """Per-user token-bucket rate limiter (rate/sec, burst capacity)."""
    rate: float = 100.0
    burst: float = 200.0
    _state: Dict[str, tuple] = field(default_factory=dict)

    def check(self, user_id: str, cost: float = 1.0, now: Optional[float] = None) -> None:
        t = time.monotonic() if now is None else now
        tokens, last = self._state.get(user_id, (self.burst, t))
        tokens = min(self.burst, tokens + (t - last) * self.rate)
        if tokens < cost:
            self._state[user_id] = (tokens, t)
            raise RateLimited(f"user {user_id}")
        self._state[user_id] = (tokens - cost, t)


@dataclass
class ContentFilter:
    """Blocklist scan over prompt token ids (stand-in for sensitive-content
    detection; real systems run a classifier here)."""
    blocked: Set[int] = field(default_factory=set)

    def check(self, tokens: Iterable[int]) -> None:
        if self.blocked:
            hit = next((t for t in tokens if int(t) in self.blocked), None)
            if hit is not None:
                raise ContentBlocked(f"token {hit}")
