"""The gateway — where the paper's end-to-end contribution lives (§4.2).

Two real, measured implementations:

``BaselineGateway`` (the FastAPI/HTTP1.1+JSON stand-in)
  - verbose OpenAI-style JSON chunks via stdlib ``json``
  - per-request connection establishment to the engine (HTTP/1.1 handshake,
    modeled as an awaited latency constant — documented in EXPERIMENTS.md)
  - a bounded sync-worker semaphore (FastAPI's threadpool under GIL): request
    validation + serde run inside it

``ScaleGateway`` (the Axum/Tokio + gRPC/protobuf adaptation)
  - compact msgpack frames (protobuf stand-in, C-speed codec)
  - connection POOL to replicas: handshake paid once per replica, not per
    request
  - fully async admission path, no sync-worker ceiling

Both share the Safety module (auth/rate-limit/content-filter), the router,
and the Observability sink, and stream per-token messages back to the client
through asyncio queues (events cross from replica threads via
``loop.call_soon_threadsafe`` — the zero-copy bridge).
"""
from __future__ import annotations

import asyncio
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Set

import numpy as np

from repro.core.engine import TokenEvent
from repro.core.metrics import Request, now
from repro.core.observability import MetricsSink, Tracer
from repro.core.router import NoReplicaAvailable, ReplicaRouter
from repro.core.safety import AuthError, Authenticator, ContentBlocked, ContentFilter, RateLimited, TokenBucket
from repro.core.serde import CODECS


@dataclass
class GatewayConfig:
    codec: str = "binary"              # "json" (baseline) | "binary" (scale)
    conn_setup_s: float = 0.0003       # per-connection handshake latency
    pooled_connections: bool = True    # pool (scale) vs per-request (baseline)
    sync_workers: int = 0              # >0: bounded sync path (baseline)
    name: str = "scale"
    # graceful degradation (DESIGN.md §5)
    max_inflight: int = 0              # >0: bounded admission; overflow is SHED
                                       # with an immediate terminal event
    default_deadline_s: Optional[float] = None   # per-request deadline default
    brownout_high: int = 0             # inflight watermark arming brown-out
                                       # (0: brown-out disabled)
    brownout_low: int = 0              # watermark disarming it (hysteresis)
    brownout_sustain_s: float = 0.5    # overload must persist this long to arm
    brownout_recover_s: float = 1.0    # calm must persist this long to disarm
    brownout_max_new_tokens: int = 8   # max_new_tokens clamp while degraded


def baseline_gateway_config() -> GatewayConfig:
    return GatewayConfig(codec="json", conn_setup_s=0.0003,
                         pooled_connections=False, sync_workers=8, name="baseline")


def scale_gateway_config() -> GatewayConfig:
    return GatewayConfig(codec="binary", conn_setup_s=0.0003,
                         pooled_connections=True, sync_workers=0, name="scale")


class Gateway:
    def __init__(self, router: ReplicaRouter, cfg: Optional[GatewayConfig] = None,
                 auth: Optional[Authenticator] = None,
                 rate_limiter: Optional[TokenBucket] = None,
                 content_filter: Optional[ContentFilter] = None,
                 sink: Optional[MetricsSink] = None,
                 require_auth: bool = False,
                 tracer: Optional[Tracer] = None):
        self.router = router
        self.cfg = cfg or scale_gateway_config()
        self.codec = CODECS[self.cfg.codec]
        self.auth = auth or Authenticator()
        self.rate_limiter = rate_limiter
        self.content_filter = content_filter
        self.sink = sink or router.sink
        self.tracer = tracer or router.tracer
        self.require_auth = require_auth
        self._pool_ready: Set[str] = set()     # replicas with a live connection
        self._sem: Optional[asyncio.Semaphore] = None
        self.requests: Dict[str, Request] = {}  # server-side registry (metrics join)
        # degradation state: inflight accounting crosses threads (admission on
        # the event loop, completion on replica threads), hence the lock
        self._degrade_lock = threading.Lock()
        self._inflight = 0
        self.inflight_max = 0                  # high-water mark (bound check)
        self.brownout = False
        self.brownout_activations = 0
        self._over_since: Optional[float] = None
        self._calm_since: Optional[float] = None

    # ------------------------------------------------------------- degradation
    def _update_brownout(self, t: float) -> None:
        """Hysteresis brown-out controller: sustained inflight above the high
        watermark arms degraded mode (clamped ``max_new_tokens``, speculative
        decoding off); sustained calm below the low watermark disarms it."""
        cfg = self.cfg
        if cfg.brownout_high <= 0:
            return
        flipped = None
        with self._degrade_lock:
            inflight = self._inflight
            if not self.brownout:
                if inflight >= cfg.brownout_high:
                    if self._over_since is None:
                        self._over_since = t
                    elif t - self._over_since >= cfg.brownout_sustain_s:
                        self.brownout = flipped = True
                        self.brownout_activations += 1
                        self._calm_since = None
                else:
                    self._over_since = None
            else:
                if inflight <= cfg.brownout_low:
                    if self._calm_since is None:
                        self._calm_since = t
                    elif t - self._calm_since >= cfg.brownout_recover_s:
                        self.brownout = False
                        flipped = False
                        self._over_since = None
                else:
                    self._calm_since = None
        if flipped is not None:
            self.sink.incr("brownout_on" if flipped else "brownout_off")
            self.router.set_degraded(flipped)

    def poll_brownout(self) -> bool:
        """Re-evaluate the brown-out controller now (recovery is time-based,
        so someone must look at the clock when traffic goes quiet)."""
        self._update_brownout(now())
        return self.brownout

    def _semaphore(self) -> Optional[asyncio.Semaphore]:
        if self.cfg.sync_workers > 0 and self._sem is None:
            self._sem = asyncio.Semaphore(self.cfg.sync_workers)
        return self._sem

    # ------------------------------------------------------------------
    async def handle(self, raw: bytes, client_q: "asyncio.Queue[bytes]",
                     auth_token: str = "") -> None:
        """Accept one streaming request. Returns after admission; tokens
        stream into ``client_q`` (b"" sentinel on error)."""
        t1 = now()
        sem = self._semaphore()
        if sem is not None:
            await sem.acquire()
        try:
            req_id, tokens, params = self.codec.decode_request(raw)
            if self.require_auth:
                user = self.auth.verify(auth_token)
            else:
                user = "anon"
            if self.rate_limiter is not None:
                self.rate_limiter.check(user)
            if self.content_filter is not None:
                self.content_filter.check(tokens)
        except (AuthError, RateLimited, ContentBlocked) as e:
            self.sink.incr(f"rejected.{type(e).__name__}")
            client_q.put_nowait(b"")
            if sem is not None:
                sem.release()
            return
        finally:
            pass

        # ---- load shedding: bounded admission. Overflow gets an immediate
        # terminal "shed" event — an explicit no, never a silent hang.
        self._update_brownout(t1)
        if self.cfg.max_inflight > 0:
            with self._degrade_lock:
                over = self._inflight >= self.cfg.max_inflight
            if over:
                request = Request(req_id=req_id,
                                  prompt_tokens=np.asarray(tokens, np.int32))
                request.t1 = t1
                request.error = "shed"
                request.finished = True
                request.t3 = now()
                self.requests[req_id] = request
                self.sink.incr("shed")
                if self.tracer:
                    self.tracer.event(req_id, "shed")
                    self.tracer.discard(req_id)
                client_q.put_nowait(self.codec.encode_token(req_id, -1, 0, True))
                if sem is not None:
                    sem.release()
                return

        max_new = int(params.get("max_new_tokens", 64))
        if self.brownout:
            # brown-out clamp: shorter generations drain the backlog faster
            max_new = min(max_new, self.cfg.brownout_max_new_tokens)
            self.sink.incr("brownout_clamped")
        request = Request(
            req_id=req_id,
            prompt_tokens=np.asarray(tokens, np.int32),
            max_new_tokens=max_new,
            temperature=float(params.get("temperature", 0.5)),
            top_p=float(params.get("top_p", 0.7)),
            greedy=bool(params.get("greedy", False)),
            user_id=user,
        )
        request.t1 = t1
        deadline_s = params.get("deadline_s", self.cfg.default_deadline_s)
        if deadline_s is not None:
            # absolute cutoff on the shared monotonic clock; enforced by the
            # engine's per-step deadline sweep
            request.deadline_s = float(deadline_s)
            request.deadline_at = t1 + float(deadline_s)
        self.requests[req_id] = request
        with self._degrade_lock:
            self._inflight += 1
            if self._inflight > self.inflight_max:
                self.inflight_max = self._inflight
        if self.tracer:
            # decode + auth/rate-limit/content checks (the sync-worker path)
            self.tracer.add(req_id, "gateway_admission", t1, now(),
                            codec=self.cfg.codec, n_prompt=len(tokens))

        loop = asyncio.get_running_loop()
        codec = self.codec

        def on_event(ev: TokenEvent) -> None:
            # replica-thread side: timestamp + encode, then hop to the loop
            r = ev.request
            if r.t4 == 0.0:
                r.t4 = ev.t_emit
            payload = codec.encode_token(r.req_id, ev.token, r.n_generated - 1,
                                         ev.finished)
            if ev.finished:
                with self._degrade_lock:
                    self._inflight -= 1
                if r.error == "deadline_exceeded":
                    self.sink.incr("deadline_exceeded")
                self._update_brownout(now())
            loop.call_soon_threadsafe(client_q.put_nowait, payload)

        # connection to the chosen replica
        try:
            replica = self.router.select()
        except NoReplicaAvailable:
            # total outage: still a terminal event, not a hang
            request.error = "no replica available"
            request.finished = True
            request.t3 = now()
            with self._degrade_lock:
                self._inflight -= 1
            self.sink.incr("no_replica")
            if self.tracer:
                self.tracer.discard(req_id)
            client_q.put_nowait(codec.encode_token(req_id, -1, 0, True))
            if sem is not None:
                sem.release()
            return
        t_conn0 = now()
        handshake = False
        if not self.cfg.pooled_connections:
            await asyncio.sleep(self.cfg.conn_setup_s)          # per-request handshake
            handshake = True
        elif replica.replica_id not in self._pool_ready:
            await asyncio.sleep(self.cfg.conn_setup_s)          # pay once, then reuse
            self._pool_ready.add(replica.replica_id)
            handshake = True
        if self.tracer and handshake:
            self.tracer.add(req_id, "connect", t_conn0, now(),
                            pooled=self.cfg.pooled_connections,
                            replica=replica.replica_id)

        self.router.submit(request, on_event, replica=replica)
        if sem is not None:
            sem.release()
