"""The gateway — where the paper's end-to-end contribution lives (§4.2).

Two real, measured implementations:

``BaselineGateway`` (the FastAPI/HTTP1.1+JSON stand-in)
  - verbose OpenAI-style JSON chunks via stdlib ``json``
  - per-request connection establishment to the engine (HTTP/1.1 handshake,
    modeled as an awaited latency constant — documented in EXPERIMENTS.md)
  - a bounded sync-worker semaphore (FastAPI's threadpool under GIL): request
    validation + serde run inside it

``ScaleGateway`` (the Axum/Tokio + gRPC/protobuf adaptation)
  - compact msgpack frames (protobuf stand-in, C-speed codec)
  - connection POOL to replicas: handshake paid once per replica, not per
    request
  - fully async admission path, no sync-worker ceiling

Both share the Safety module (auth/rate-limit/content-filter), the router,
and the Observability sink, and stream per-token messages back to the client
through asyncio queues (events cross from replica threads via
``loop.call_soon_threadsafe`` — the zero-copy bridge).
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import Dict, Optional, Set

import numpy as np

from repro.core.engine import TokenEvent
from repro.core.metrics import Request, now
from repro.core.observability import MetricsSink, Tracer
from repro.core.router import ReplicaRouter
from repro.core.safety import AuthError, Authenticator, ContentBlocked, ContentFilter, RateLimited, TokenBucket
from repro.core.serde import CODECS


@dataclass
class GatewayConfig:
    codec: str = "binary"              # "json" (baseline) | "binary" (scale)
    conn_setup_s: float = 0.0003       # per-connection handshake latency
    pooled_connections: bool = True    # pool (scale) vs per-request (baseline)
    sync_workers: int = 0              # >0: bounded sync path (baseline)
    name: str = "scale"


def baseline_gateway_config() -> GatewayConfig:
    return GatewayConfig(codec="json", conn_setup_s=0.0003,
                         pooled_connections=False, sync_workers=8, name="baseline")


def scale_gateway_config() -> GatewayConfig:
    return GatewayConfig(codec="binary", conn_setup_s=0.0003,
                         pooled_connections=True, sync_workers=0, name="scale")


class Gateway:
    def __init__(self, router: ReplicaRouter, cfg: Optional[GatewayConfig] = None,
                 auth: Optional[Authenticator] = None,
                 rate_limiter: Optional[TokenBucket] = None,
                 content_filter: Optional[ContentFilter] = None,
                 sink: Optional[MetricsSink] = None,
                 require_auth: bool = False,
                 tracer: Optional[Tracer] = None):
        self.router = router
        self.cfg = cfg or scale_gateway_config()
        self.codec = CODECS[self.cfg.codec]
        self.auth = auth or Authenticator()
        self.rate_limiter = rate_limiter
        self.content_filter = content_filter
        self.sink = sink or router.sink
        self.tracer = tracer or router.tracer
        self.require_auth = require_auth
        self._pool_ready: Set[str] = set()     # replicas with a live connection
        self._sem: Optional[asyncio.Semaphore] = None
        self.requests: Dict[str, Request] = {}  # server-side registry (metrics join)

    def _semaphore(self) -> Optional[asyncio.Semaphore]:
        if self.cfg.sync_workers > 0 and self._sem is None:
            self._sem = asyncio.Semaphore(self.cfg.sync_workers)
        return self._sem

    # ------------------------------------------------------------------
    async def handle(self, raw: bytes, client_q: "asyncio.Queue[bytes]",
                     auth_token: str = "") -> None:
        """Accept one streaming request. Returns after admission; tokens
        stream into ``client_q`` (b"" sentinel on error)."""
        t1 = now()
        sem = self._semaphore()
        if sem is not None:
            await sem.acquire()
        try:
            req_id, tokens, params = self.codec.decode_request(raw)
            if self.require_auth:
                user = self.auth.verify(auth_token)
            else:
                user = "anon"
            if self.rate_limiter is not None:
                self.rate_limiter.check(user)
            if self.content_filter is not None:
                self.content_filter.check(tokens)
        except (AuthError, RateLimited, ContentBlocked) as e:
            self.sink.incr(f"rejected.{type(e).__name__}")
            client_q.put_nowait(b"")
            if sem is not None:
                sem.release()
            return
        finally:
            pass

        request = Request(
            req_id=req_id,
            prompt_tokens=np.asarray(tokens, np.int32),
            max_new_tokens=int(params.get("max_new_tokens", 64)),
            temperature=float(params.get("temperature", 0.5)),
            top_p=float(params.get("top_p", 0.7)),
            user_id=user,
        )
        request.t1 = t1
        self.requests[req_id] = request
        if self.tracer:
            # decode + auth/rate-limit/content checks (the sync-worker path)
            self.tracer.add(req_id, "gateway_admission", t1, now(),
                            codec=self.cfg.codec, n_prompt=len(tokens))

        loop = asyncio.get_running_loop()
        codec = self.codec

        def on_event(ev: TokenEvent) -> None:
            # replica-thread side: timestamp + encode, then hop to the loop
            r = ev.request
            if r.t4 == 0.0:
                r.t4 = ev.t_emit
            payload = codec.encode_token(r.req_id, ev.token, r.n_generated - 1,
                                         ev.finished)
            loop.call_soon_threadsafe(client_q.put_nowait, payload)

        # connection to the chosen replica
        replica = self.router.select()
        t_conn0 = now()
        handshake = False
        if not self.cfg.pooled_connections:
            await asyncio.sleep(self.cfg.conn_setup_s)          # per-request handshake
            handshake = True
        elif replica.replica_id not in self._pool_ready:
            await asyncio.sleep(self.cfg.conn_setup_s)          # pay once, then reuse
            self._pool_ready.add(replica.replica_id)
            handshake = True
        if self.tracer and handshake:
            self.tracer.add(req_id, "connect", t_conn0, now(),
                            pooled=self.cfg.pooled_connections,
                            replica=replica.replica_id)

        self.router.submit(request, on_event, replica=replica)
        if sem is not None:
            sem.release()
