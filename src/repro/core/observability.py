"""Observability module: counters + a metrics sink that persists request
records to local disk (paper Figure 1's Observability Module)."""
from __future__ import annotations

import os
import threading
from collections import defaultdict
from dataclasses import asdict
from typing import Any, Dict, List, Optional

import orjson

from repro.core.metrics import Request, request_metrics


class MetricsSink:
    """Thread-safe in-memory counters + optional async JSONL persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.counters: Dict[str, float] = defaultdict(float)
        self._records: List[bytes] = []
        self._lock = threading.Lock()

    def incr(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def record_request(self, r: Request) -> None:
        m = request_metrics(r)
        rec = orjson.dumps({"kind": "request", **asdict(m)})
        with self._lock:
            self._records.append(rec)
            self.counters["requests_completed"] += 1
            self.counters["tokens_generated"] += r.n_generated

    def record(self, kind: str, **fields: Any) -> None:
        rec = orjson.dumps({"kind": kind, **fields})
        with self._lock:
            self._records.append(rec)

    def flush(self) -> int:
        """Persist buffered records to disk; returns count written."""
        with self._lock:
            records, self._records = self._records, []
        if self.path and records:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(self.path, "ab") as f:
                f.write(b"\n".join(records) + b"\n")
        return len(records)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.counters)
