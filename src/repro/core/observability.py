"""Observability module: counters + a metrics sink that persists request
records to local disk (paper Figure 1's Observability Module)."""
from __future__ import annotations

import os
import threading
from collections import defaultdict
from dataclasses import asdict
from typing import Any, Dict, List, Optional

try:                                    # orjson is optional (3-10x faster)
    import orjson as _orjson
except ImportError:                     # stdlib fallback keeps the module importable
    _orjson = None
    import json as _json

from repro.core.metrics import Request, request_metrics


def _dumps(obj: Any) -> bytes:
    if _orjson is not None:
        return _orjson.dumps(obj)
    return _json.dumps(obj, default=str, separators=(",", ":")).encode()


class MetricsSink:
    """Thread-safe in-memory counters + optional async JSONL persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.counters: Dict[str, float] = defaultdict(float)
        self._records: List[bytes] = []
        self._lock = threading.Lock()

    def incr(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def record_request(self, r: Request) -> None:
        m = request_metrics(r)
        rec = _dumps({"kind": "request", **asdict(m)})
        with self._lock:
            self._records.append(rec)
            self.counters["requests_completed"] += 1
            self.counters["tokens_generated"] += r.n_generated

    def record(self, kind: str, **fields: Any) -> None:
        rec = _dumps({"kind": kind, **fields})
        with self._lock:
            self._records.append(rec)

    def record_engine(self, engine_id: str, stats: Dict[str, float]) -> None:
        """Snapshot an engine's cumulative counters (``InferenceEngine.stats``):
        prefix-cache hit/miss pages, COW copies, evictions, hit-rate gauge.
        Cumulative counters become gauges (last value wins)."""
        rec = _dumps({"kind": "engine", "engine_id": engine_id, **stats})
        with self._lock:
            self._records.append(rec)
            for k, v in stats.items():
                self.counters[f"engine.{k}"] = float(v)

    def flush(self) -> int:
        """Persist buffered records to disk; returns count written."""
        with self._lock:
            records, self._records = self._records, []
        if self.path and records:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(self.path, "ab") as f:
                f.write(b"\n".join(records) + b"\n")
        return len(records)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.counters)
