"""Observability module (paper Figure 1's Observability Module).

Three layers:

``Tracer`` / ``Span``
  Per-request span lists covering the whole serving path: gateway
  admission, routing, queue wait, each prefill chunk, decode runs,
  speculative verify sweeps, COW copies, preemption/resume. Spans use the
  monotonic clock (``metrics.now``), are collected under one lock, and the
  whole tracer is a no-op when disabled (``Tracer(enabled=False)`` or a
  ``None`` tracer on the instrumented component) — the hot path pays one
  truthiness check. Consecutive same-name spans of a request can be
  coalesced (``merge=True``) so a thousand decode iterations become a few
  "decode run" spans instead of a thousand entries.

``MetricsSink``
  Thread-safe in-memory counters + JSONL persistence. Records buffer in
  memory and reach disk on ``flush()``; with ``flush_interval_s`` a daemon
  thread flushes periodically, and sinks with a path always flush once
  more at interpreter exit (``atexit``) or on ``close()``, so a benchmark
  that crashes mid-run still leaves its records on disk.

Timeline aggregation (windowed percentiles, SLO attainment) lives in
``repro.core.timeline``; the per-iteration engine profile is
``InferenceEngine.step_records``.
"""
from __future__ import annotations

import atexit
import os
import threading
import weakref
from collections import defaultdict, deque
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

try:                                    # orjson is optional (3-10x faster)
    import orjson as _orjson
except ImportError:                     # stdlib fallback keeps the module importable
    _orjson = None
    import json as _json

from repro.core.metrics import Request, now, request_metrics


def _dumps(obj: Any) -> bytes:
    if _orjson is not None:
        return _orjson.dumps(obj)
    return _json.dumps(obj, default=str, separators=(",", ":")).encode()


# ----------------------------------------------------------------- tracing
@dataclass
class Span:
    """One attributed stage of a request's life. ``t0``/``t1`` are
    monotonic-clock seconds (same clock as the Figure-4 timestamps);
    instant events carry t0 == t1."""
    name: str
    t0: float
    t1: float
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Thread-safe per-request span collector.

    ``begin``/``end`` bracket an open stage (keyed by request + name, e.g.
    the queue wait closed at admission); ``add`` records a closed span;
    ``event`` records an instant. ``pop`` removes and returns a request's
    ordered span list for export. Bounded: at most ``max_spans`` spans per
    request (overflow counted in ``dropped_spans``) and ``max_requests``
    tracked requests (oldest evicted), so an exporter that never pops a
    cancelled request cannot leak memory.

    A disabled tracer is falsy — instrumentation guards with
    ``if tracer: ...`` and pays nothing else.
    """

    def __init__(self, enabled: bool = True, max_spans: int = 512,
                 max_requests: int = 8192):
        self.enabled = enabled
        self.max_spans = max_spans
        self.max_requests = max_requests
        self._spans: Dict[str, List[Span]] = {}
        self._open: Dict[Tuple[str, str], Span] = {}
        self._order: deque = deque()        # req_id insertion order (eviction)
        self._lock = threading.Lock()
        self.dropped_spans = 0
        self.evicted_requests = 0

    def __bool__(self) -> bool:
        return self.enabled

    # -- internal: caller holds the lock
    def _bucket(self, req_id: str) -> List[Span]:
        spans = self._spans.get(req_id)
        if spans is None:
            spans = self._spans[req_id] = []
            self._order.append(req_id)
            while len(self._spans) > self.max_requests and self._order:
                victim = self._order.popleft()
                if victim in self._spans:
                    del self._spans[victim]
                    self.evicted_requests += 1
        return spans

    def _append(self, req_id: str, span: Span, merge: bool) -> None:
        spans = self._bucket(req_id)
        if merge and spans and spans[-1].name == span.name:
            last = spans[-1]
            last.t1 = span.t1
            for k, v in span.attrs.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    last.attrs[k] = last.attrs.get(k, 0) + v
                else:
                    last.attrs[k] = v
            return
        if len(spans) >= self.max_spans:
            self.dropped_spans += 1
            return
        spans.append(span)

    # -- public API (all no-ops when disabled)
    def begin(self, req_id: str, name: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        t = now()
        with self._lock:
            self._open[(req_id, name)] = Span(name, t, t, dict(attrs))

    def end(self, req_id: str, name: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        t = now()
        with self._lock:
            span = self._open.pop((req_id, name), None)
            if span is None:
                return
            span.t1 = t
            span.attrs.update(attrs)
            self._append(req_id, span, merge=False)

    def add(self, req_id: str, name: str, t0: float, t1: float,
            merge: bool = False, **attrs: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._append(req_id, Span(name, t0, t1, dict(attrs)), merge)

    def event(self, req_id: str, name: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        t = now()
        with self._lock:
            self._append(req_id, Span(name, t, t, dict(attrs)), merge=False)

    def pop(self, req_id: str) -> List[Span]:
        """Remove and return the request's spans (ordered by insertion).
        Open (unclosed) spans for the request are dropped."""
        with self._lock:
            spans = self._spans.pop(req_id, [])
            for key in [k for k in self._open if k[0] == req_id]:
                del self._open[key]
            return spans

    def discard(self, req_id: str) -> None:
        self.pop(req_id)

    def peek(self, req_id: str) -> List[Span]:
        with self._lock:
            return list(self._spans.get(req_id, ()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


def spans_to_dicts(spans: List[Span]) -> List[Dict[str, Any]]:
    return [asdict(s) for s in spans]


# ------------------------------------------------------------------- sink
# Sinks with a path register here once; a single atexit hook flushes any
# still alive at interpreter exit (weak refs: a collected sink is skipped).
_LIVE_SINKS: "weakref.WeakSet" = weakref.WeakSet()
_ATEXIT_ARMED = False


def _flush_live_sinks() -> None:
    for sink in list(_LIVE_SINKS):
        try:
            sink.close()
        except Exception:
            pass


class MetricsSink:
    """Thread-safe in-memory counters + JSONL persistence with optional
    periodic auto-flush (``flush_interval_s``) and a guaranteed exit-time
    flush (``close()`` / ``atexit``) for sinks that have a path."""

    def __init__(self, path: Optional[str] = None,
                 flush_interval_s: Optional[float] = None):
        self.path = path
        self.counters: Dict[str, float] = defaultdict(float)
        self._records: List[bytes] = []
        self._lock = threading.Lock()
        self._closed = False
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        if path is not None:
            global _ATEXIT_ARMED
            _LIVE_SINKS.add(self)
            if not _ATEXIT_ARMED:
                atexit.register(_flush_live_sinks)
                _ATEXIT_ARMED = True
        if flush_interval_s is not None and path is not None:
            self._flusher = threading.Thread(
                target=self._flush_loop, args=(float(flush_interval_s),),
                name="metrics-sink-flush", daemon=True)
            self._flusher.start()

    def _flush_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            self.flush()

    def incr(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def observe(self, name: str, value: float) -> None:
        """One observation of a distribution-valued metric (e.g. failover
        latency): keeps ``.count`` / ``.sum`` / ``.max`` counters so the
        snapshot exposes mean and worst case without storing samples."""
        with self._lock:
            self.counters[f"{name}.count"] += 1
            self.counters[f"{name}.sum"] += value
            if value > self.counters[f"{name}.max"]:
                self.counters[f"{name}.max"] = value

    def record_request(self, r: Request) -> None:
        m = request_metrics(r)
        rec = _dumps({"kind": "request", **asdict(m)})
        with self._lock:
            self._records.append(rec)
            self.counters["requests_completed"] += 1
            self.counters["tokens_generated"] += r.n_generated

    def record(self, kind: str, **fields: Any) -> None:
        rec = _dumps({"kind": kind, **fields})
        with self._lock:
            self._records.append(rec)

    def record_trace(self, r: Request, spans: List[Span]) -> None:
        """Export a finished request's span list (DESIGN.md §4) alongside
        the Figure-4 timestamps it must reconcile with."""
        rec = _dumps({
            "kind": "trace", "req_id": r.req_id, "replica_id": r.replica_id,
            "t0": r.t0, "t1": r.t1, "t2": r.t2, "t3": r.t3, "t4": r.t4,
            "t5": r.t5, "t6": r.t6, "n_generated": r.n_generated,
            "preemptions": r.preemptions, "spans": spans_to_dicts(spans),
        })
        with self._lock:
            self._records.append(rec)
            self.counters["traces_exported"] += 1

    def record_engine(self, engine_id: str, stats: Dict[str, float]) -> None:
        """Snapshot an engine's cumulative counters (``InferenceEngine.stats``):
        prefix-cache hit/miss pages, COW copies, evictions, hit-rate gauge.
        Cumulative counters become gauges (last value wins)."""
        rec = _dumps({"kind": "engine", "engine_id": engine_id, **stats})
        with self._lock:
            self._records.append(rec)
            for k, v in stats.items():
                self.counters[f"engine.{k}"] = float(v)

    def flush(self) -> int:
        """Persist buffered records to disk; returns count written."""
        with self._lock:
            records, self._records = self._records, []
        if self.path and records:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(self.path, "ab") as f:
                f.write(b"\n".join(records) + b"\n")
        return len(records)

    def close(self) -> int:
        """Stop the auto-flusher and flush whatever is buffered. Idempotent;
        also runs via ``atexit`` for sinks with a path."""
        first = False
        with self._lock:
            if not self._closed:
                self._closed = True
                first = True
        if first:
            self._stop.set()
            if self._flusher is not None and self._flusher.is_alive():
                self._flusher.join(timeout=5)
        return self.flush()

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.counters)
