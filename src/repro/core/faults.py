"""Deterministic fault injection for the serving stack (DESIGN.md §5).

A :class:`FaultPlan` is an explicit, seeded schedule of fault events; a
:class:`FaultInjector` evaluates it against a monotonic clock at three hook
points:

  * ``Replica._loop``        — replica crashes (the serving thread exits
                               without cleanup) and step stalls (the loop
                               freezes: no stepping, no inbox drain, no
                               heartbeat)
  * ``InferenceEngine.step`` — slow-step latency multipliers (sleep scaled
                               by the previous step's measured duration) and
                               artificial KV page pressure (pages held out
                               of the allocator's free list for a window)
  * ``ReplicaRouter.submit`` — transient submit errors
                               (:class:`TransientSubmitError`), retried by
                               the router's retry budget

Everything is reproducible from ``(plan, seed)``: the only stochastic
choice — whether a given submit attempt fails inside an error window — is
a pure hash of ``(seed, req_id, attempt)``, so it does not depend on
thread interleaving. The injector never mutates serving state directly; it
only tells the hook site what to do, so a ``None`` injector costs one
attribute check on the hot path.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# fault kinds understood by the hook points
KINDS = ("crash", "stall", "slow", "submit_error", "kv_pressure")


class TransientSubmitError(Exception):
    """A submit attempt failed for a transient reason (injected network
    blip / replica hiccup). The router's retry budget handles these."""


@dataclass
class FaultEvent:
    """One scheduled fault. ``at_s`` is the offset from ``FaultInjector.
    start()``; windowed kinds (stall/slow/submit_error/kv_pressure) are
    active for ``duration_s`` from ``at_s``; ``crash`` fires once at
    ``at_s``. ``replica_id=None`` matches any replica (submit_error is
    typically router-wide)."""
    kind: str
    at_s: float
    replica_id: Optional[str] = None
    duration_s: float = 0.0
    factor: float = 1.0          # slow: multiplier on the previous step time
    delay_s: float = 0.0         # slow: additive per-step delay
    prob: float = 1.0            # submit_error: per-attempt failure prob
    pages: int = 0               # kv_pressure: pages held out of the pool

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {', '.join(KINDS)})")


@dataclass
class FaultPlan:
    """A seeded schedule of fault events. The plan is data — serializable,
    diffable, and replayable; the seed only drives the injector's
    per-attempt coin flips."""
    events: List[FaultEvent] = field(default_factory=list)
    seed: int = 0

    def crash(self, replica_id: str, at_s: float) -> "FaultPlan":
        self.events.append(FaultEvent("crash", at_s, replica_id))
        return self

    def stall(self, replica_id: str, at_s: float, duration_s: float) -> "FaultPlan":
        self.events.append(FaultEvent("stall", at_s, replica_id,
                                      duration_s=duration_s))
        return self

    def slow(self, replica_id: Optional[str], at_s: float, duration_s: float,
             factor: float = 2.0, delay_s: float = 0.0) -> "FaultPlan":
        self.events.append(FaultEvent("slow", at_s, replica_id,
                                      duration_s=duration_s, factor=factor,
                                      delay_s=delay_s))
        return self

    def submit_error(self, at_s: float, duration_s: float,
                     prob: float = 1.0) -> "FaultPlan":
        self.events.append(FaultEvent("submit_error", at_s, None,
                                      duration_s=duration_s, prob=prob))
        return self

    def kv_pressure(self, replica_id: Optional[str], at_s: float,
                    duration_s: float, pages: int) -> "FaultPlan":
        self.events.append(FaultEvent("kv_pressure", at_s, replica_id,
                                      duration_s=duration_s, pages=pages))
        return self


class FaultInjector:
    """Evaluates a :class:`FaultPlan` at the serving stack's hook points.
    Thread-safe: hooks are called from replica threads, the router monitor,
    and the gateway's event loop concurrently."""

    HOLD_KEY = "fault"               # allocator hold bucket for kv_pressure

    def __init__(self, plan: Optional[FaultPlan] = None,
                 clock=time.monotonic):
        self.plan = plan or FaultPlan()
        self.clock = clock
        self._t0: Optional[float] = None
        self._lock = threading.Lock()
        self._fired_crashes: set = set()
        self.injected: Counter = Counter()   # per-kind fire/active counts

    # ------------------------------------------------------------- clock
    def start(self) -> "FaultInjector":
        """Arm the schedule; ``at_s`` offsets are relative to this call.
        Auto-armed on first hook evaluation if never called."""
        with self._lock:
            if self._t0 is None:
                self._t0 = self.clock()
        return self

    def elapsed(self) -> float:
        if self._t0 is None:
            self.start()
        return self.clock() - self._t0

    # ------------------------------------------------------------- matching
    def _match(self, kind: str, replica_id: Optional[str],
               t: float) -> Optional[FaultEvent]:
        """First active event of ``kind`` applying to ``replica_id``."""
        for ev in self.plan.events:
            if ev.kind != kind:
                continue
            if ev.replica_id is not None and ev.replica_id != replica_id:
                continue
            if kind == "crash":
                if t >= ev.at_s:
                    return ev
            elif ev.at_s <= t < ev.at_s + ev.duration_s:
                return ev
        return None

    def _coin(self, *key) -> float:
        """Deterministic uniform [0, 1) from (seed, key): independent of
        call order and thread interleaving, so an injected schedule replays
        bit-identically."""
        data = repr((self.plan.seed,) + key).encode()
        h = hashlib.blake2b(data, digest_size=8).digest()
        return int.from_bytes(h, "little") / 2.0 ** 64

    # ------------------------------------------------------------- hooks
    def replica_action(self, replica_id: str) -> Optional[Tuple[str, float]]:
        """Called by ``Replica._loop`` once per loop iteration. Returns
        ``("crash", 0.0)`` exactly once when a crash is due, ``("stall",
        remaining_s)`` while a stall window is open, else ``None``."""
        t = self.elapsed()
        ev = self._match("crash", replica_id, t)
        if ev is not None:
            with self._lock:
                if (replica_id, id(ev)) not in self._fired_crashes:
                    self._fired_crashes.add((replica_id, id(ev)))
                    self.injected["crash"] += 1
                    return ("crash", 0.0)
        ev = self._match("stall", replica_id, t)
        if ev is not None:
            self.injected["stall_ticks"] += 1
            return ("stall", ev.at_s + ev.duration_s - t)
        return None

    def on_engine_step(self, engine) -> None:
        """Called by ``InferenceEngine.step`` before each iteration: applies
        slow-step latency (factor x previous measured step duration +
        additive delay) and adjusts the artificial KV hold."""
        key = getattr(engine, "fault_key", None)
        t = self.elapsed()
        ev = self._match("slow", key, t)
        if ev is not None:
            base = 0.0
            records = getattr(engine, "step_records", None)
            if records:
                base = max(records[-1].duration, 0.0)
            delay = max(ev.factor - 1.0, 0.0) * base + ev.delay_s
            if delay > 0:
                self.injected["slow_steps"] += 1
                time.sleep(min(delay, 1.0))
        alloc = getattr(engine, "allocator", None)
        if alloc is not None:
            ev = self._match("kv_pressure", key, t)
            want = ev.pages if ev is not None else 0
            held = alloc.held_pages(self.HOLD_KEY)
            if want != held:
                alloc.release_hold(self.HOLD_KEY)
                if want > 0:
                    got = alloc.hold(want, self.HOLD_KEY)
                    if got and held == 0:
                        self.injected["kv_pressure"] += 1

    def on_submit(self, replica_id: str, req_id: str, attempt: int) -> None:
        """Called by ``ReplicaRouter.submit`` before handing a request to a
        replica. Raises :class:`TransientSubmitError` when an error window
        is open and the (req_id, attempt) coin lands under ``prob``."""
        ev = self._match("submit_error", replica_id, self.elapsed())
        if ev is None:
            return
        if self._coin("submit", req_id, attempt) < ev.prob:
            self.injected["submit_error"] += 1
            raise TransientSubmitError(
                f"injected submit error for {req_id} (attempt {attempt})")

    # ------------------------------------------------------------- teardown
    def release_holds(self, engines) -> None:
        """Return any artificially held KV pages (end-of-run cleanup so the
        leak check sees the allocator's true state)."""
        for engine in engines:
            alloc = getattr(engine, "allocator", None)
            if alloc is not None:
                alloc.release_hold(self.HOLD_KEY)
