"""Paged KV cache management (host side): the PagedAttention resource model.

The device side is a global physical page pool per layer (see
``LM.init_cache(kind="paged")`` and the Pallas paged_attention kernel); this
module owns the *allocator*: free-page list, per-slot page tables, and the
capacity queries the scheduler's max-utilization policy needs.

Invariants (property-tested):
  - a physical page is owned by at most one slot at any time
  - free + allocated == total
  - page_table entries for a slot cover ceil(len/page_size) pages exactly
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class OutOfPages(Exception):
    pass


@dataclass
class PagedAllocator:
    num_pages: int
    page_size: int
    max_pages_per_seq: int

    def __post_init__(self):
        # page 0 is reserved as the "null" page so uninitialized page-table
        # entries never alias a live page
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}

    # ---------------- queries ----------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return (n_tokens + self.page_size - 1) // self.page_size

    def can_allocate(self, slot: int, n_tokens: int) -> bool:
        have = len(self._owned.get(slot, []))
        need = self.pages_needed(n_tokens) - have
        return need <= len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.num_pages - 1, 1)

    # ---------------- mutations ----------------
    def allocate(self, slot: int, n_tokens: int) -> List[int]:
        """Ensure `slot` owns enough pages for n_tokens; returns newly added."""
        owned = self._owned.setdefault(slot, [])
        need = self.pages_needed(n_tokens) - len(owned)
        if need > len(self._free):
            raise OutOfPages(f"slot {slot}: need {need}, free {len(self._free)}")
        if len(owned) + max(need, 0) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: exceeds max_pages_per_seq")
        new = [self._free.pop() for _ in range(max(need, 0))]
        owned.extend(new)
        return new

    def free(self, slot: int) -> int:
        owned = self._owned.pop(slot, [])
        self._free.extend(owned)
        return len(owned)

    def page_table_row(self, slot: int) -> np.ndarray:
        row = np.zeros(self.max_pages_per_seq, np.int32)
        owned = self._owned.get(slot, [])
        row[: len(owned)] = owned
        return row

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    def check_invariants(self) -> None:
        allocated = [p for pages in self._owned.values() for p in pages]
        assert len(set(allocated)) == len(allocated), "page double-owned"
        assert set(allocated).isdisjoint(self._free), "page both free and owned"
        assert len(allocated) + len(self._free) == self.num_pages - 1, "page leak"
