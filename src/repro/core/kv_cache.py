"""Paged KV cache management (host side): the PagedAttention resource model
with refcounted pages, copy-on-write, and an automatic prefix cache.

The device side is a global physical page pool per layer (see
``LM.init_cache(kind="paged")`` and the Pallas paged_attention kernel); this
module owns the *allocator*: free-page list, per-slot page tables, refcounts,
the LRU pool of retired-but-cached pages, and the capacity queries the
scheduler's max-utilization policy needs.

Page lifecycle (DESIGN.md §2):

    free ──allocate──▶ exclusive (ref 1) ──share──▶ shared (ref > 1)
      ▲                    │  ▲                        │
      │                    │  └────── COW copy ◀───────┘  (write to a shared
      │              free(slot), not cached               or cached page)
      │                    │
      │                    ▼        free(slot), cached
      └──evict (LRU)── retired (ref 0, content kept, reusable via the trie)

A page whose refcount drops to 0 is only returned to the free list if the
prefix cache holds no node for it; otherwise it is *retired* to an LRU pool,
where its contents stay valid and a later request with the same prompt
prefix can revive it with a pure page-table update (no prefill). Retired
pages are reclaimed (LRU order) before ``OutOfPages``/preemption fires, so
the prefix cache multiplies effective pool capacity instead of consuming it.

Invariants (property-tested in tests/test_kv_cache.py):
  - referenced + free + retired == total - 1 (page 0 reserved)
  - sum of refcounts == sum of per-slot ownership counts
  - a page with refcount > 1 (or registered in the trie) is never written:
    writers must call ``ensure_exclusive`` first (copy-on-write)
  - eviction only ever takes refcount-0 pages
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class OutOfPages(Exception):
    pass


@dataclass
class PagedAllocator:
    num_pages: int
    page_size: int
    max_pages_per_seq: int

    def __post_init__(self):
        # page 0 is reserved as the "null" page so uninitialized page-table
        # entries never alias a live page
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}
        # retired pages: refcount 0 but still holding prefix-cache content;
        # ordered oldest-first so popitem(last=False) is the LRU victim
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # pages the prefix cache holds a node for (content must not mutate)
        self._cached: set = set()
        # artificially held pages (fault injection: simulated page pressure),
        # keyed by hold name; excluded from the free list until released
        self._held: Dict[str, List[int]] = {}
        # called with the page id when a retired page is reclaimed, so the
        # prefix cache can drop its node
        self.on_evict: Optional[Callable[[int], None]] = None
        self.evicted_pages = 0
        self.cow_copies = 0

    # ---------------- queries ----------------
    @property
    def free_pages(self) -> int:
        """Allocatable capacity: the free list plus reclaimable retired pages."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - self.free_pages

    @property
    def retired_pages(self) -> int:
        return len(self._lru)

    @property
    def live_pages(self) -> int:
        """Pages currently referenced by at least one slot. Zero when every
        sequence has finished — the leak check the chaos benchmarks gate on
        (retired prefix-cache pages are refcount-0 and do not count)."""
        return len(self._ref)

    def pages_needed(self, n_tokens: int) -> int:
        return (n_tokens + self.page_size - 1) // self.page_size

    def can_allocate(self, slot: int, n_tokens: int) -> bool:
        have = len(self._owned.get(slot, []))
        need = self.pages_needed(n_tokens) - have
        if have + max(need, 0) > self.max_pages_per_seq:
            return False
        return need <= self.free_pages

    def utilization(self) -> float:
        return self.used_pages / max(self.num_pages - 1, 1)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def retired(self, page: int) -> bool:
        """True if the page sits in the LRU pool: its content is reusable but
        reviving it consumes capacity that ``free_pages`` counts."""
        return page in self._lru

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    # ---------------- mutations ----------------
    def _take_page(self) -> int:
        """Pop a writable page: free list first, then evict the LRU retired
        page (its prefix-cache node is dropped via ``on_evict``)."""
        if self._free:
            return self._free.pop()
        if self._lru:
            page, _ = self._lru.popitem(last=False)
            self._cached.discard(page)
            self.evicted_pages += 1
            if self.on_evict is not None:
                self.on_evict(page)
            return page
        raise OutOfPages("pool exhausted")

    def _decref(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            if page in self._cached:
                self._lru[page] = None     # retire: content stays reusable
            else:
                self._free.append(page)

    def allocate(self, slot: int, n_tokens: int) -> List[int]:
        """Ensure `slot` owns enough pages for n_tokens; returns newly added."""
        owned = self._owned.setdefault(slot, [])
        need = self.pages_needed(n_tokens) - len(owned)
        if need > self.free_pages:
            raise OutOfPages(f"slot {slot}: need {need}, free {self.free_pages}")
        if len(owned) + max(need, 0) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: exceeds max_pages_per_seq")
        new = [self._take_page() for _ in range(max(need, 0))]
        for p in new:
            self._ref[p] = 1
        owned.extend(new)
        return new

    def share(self, slot: int, pages: Sequence[int]) -> None:
        """Map existing physical pages into ``slot``'s table (prefix-cache
        hit): each page's refcount rises; retired pages are revived out of
        the LRU pool. Must form the slot's leading pages (called once, at
        admission, before any allocate)."""
        owned = self._owned.setdefault(slot, [])
        assert not owned, "share() must precede allocate() for a slot"
        if len(pages) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: exceeds max_pages_per_seq")
        for p in pages:
            if p in self._ref:
                self._ref[p] += 1
            else:
                self._lru.pop(p, None)     # revive retired page
                self._ref[p] = 1
            owned.append(p)

    def ensure_exclusive(self, slot: int, first_block: int, last_block: int,
                         copies: Optional[List[Tuple[int, int]]] = None
                         ) -> List[Tuple[int, int]]:
        """Copy-on-write: make the slot's logical pages [first_block,
        last_block] safe to write. A page that is shared (refcount > 1) or
        registered in the prefix cache is replaced by a fresh page; the
        returned (src, dst) pairs must be applied as device-side page copies
        BEFORE the write lands. Never mutates a page with refcount > 1.

        Pairs are appended to ``copies`` when given, so they survive an
        ``OutOfPages`` raised partway through the range: blocks detached
        before the abort already point at fresh pages holding garbage, and a
        retrying caller (scheduler.make_writable) must still apply their
        device copies — dropping them would leave uninitialized KV where
        cached prefix content was expected."""
        if copies is None:
            copies = []
        owned = self._owned.get(slot, [])
        for i in range(max(first_block, 0), min(last_block + 1, len(owned))):
            p = owned[i]
            if self._ref[p] > 1 or p in self._cached:
                dst = self._take_page()    # before decref: dst must not be p
                self._ref[dst] = 1
                self._decref(p)
                owned[i] = dst
                copies.append((p, dst))
                self.cow_copies += 1
        return copies

    def free(self, slot: int) -> int:
        """Drop the slot's references. Pages reaching refcount 0 go back to
        the free list, or retire to the LRU pool if the prefix cache still
        points at them."""
        owned = self._owned.pop(slot, [])
        for p in owned:
            self._decref(p)
        return len(owned)

    def truncate(self, slot: int, n_pages: int) -> int:
        """Release the slot's trailing pages beyond its first ``n_pages``
        (speculative-decode rollback: pages grown for rejected draft tokens
        go straight back). Each dropped page is decref'd — a shared page
        loses one reference, a trie-registered page retires to the LRU pool
        with its content intact, an exclusive uncached page returns to the
        free list. Returns the number of pages dropped."""
        owned = self._owned.get(slot, [])
        dropped = 0
        while len(owned) > max(n_pages, 0):
            self._decref(owned.pop())
            dropped += 1
        return dropped

    # ---------------- fault-injection holds ----------------
    def hold(self, n_pages: int, key: str = "fault") -> int:
        """Artificial page pressure (fault injection): move up to ``n_pages``
        pages from the free list into the named hold, where ``free_pages``
        no longer counts them. Only truly free pages are taken — never
        retired (prefix-cache) pages, so injected pressure squeezes capacity
        without silently wiping cached content. Returns the count held."""
        bucket = self._held.setdefault(key, [])
        take = min(max(n_pages, 0), len(self._free))
        for _ in range(take):
            bucket.append(self._free.pop())
        return take

    def held_pages(self, key: str = "fault") -> int:
        return len(self._held.get(key, ()))

    def release_hold(self, key: str = "fault") -> int:
        """Return a named hold's pages to the free list."""
        bucket = self._held.pop(key, [])
        self._free.extend(bucket)
        return len(bucket)

    # ---------------- prefix-cache hooks ----------------
    def mark_cached(self, page: int) -> None:
        self._cached.add(page)

    def unmark_cached(self, page: int) -> None:
        self._cached.discard(page)
        if page in self._lru:               # retired with no node left: free it
            del self._lru[page]
            self._free.append(page)

    # ---------------- page-table export ----------------
    def page_table_row(self, slot: int) -> np.ndarray:
        row = np.zeros(self.max_pages_per_seq, np.int32)
        owned = self._owned.get(slot, [])
        row[: len(owned)] = owned
        return row

    def check_invariants(self) -> None:
        refs = self._ref
        assert all(r >= 1 for r in refs.values()), "zero/negative refcount kept"
        own_counts: Dict[int, int] = {}
        for pages in self._owned.values():
            for p in pages:
                own_counts[p] = own_counts.get(p, 0) + 1
        assert own_counts == dict(refs), "refcounts != ownership counts"
        live, free, lru = set(refs), set(self._free), set(self._lru)
        held = {p for pages in self._held.values() for p in pages}
        assert live.isdisjoint(free) and live.isdisjoint(lru), \
            "page both referenced and free/retired"
        assert free.isdisjoint(lru), "page both free and retired"
        assert held.isdisjoint(live | free | lru), "held page escaped the hold"
        assert len(live) + len(free) + len(lru) + len(held) \
            == self.num_pages - 1, "page leak"
        assert 0 not in live | free | lru | held, "null page escaped"
        assert self._cached <= live | lru, "cached page neither live nor retired"


# ---------------------------------------------------------------------------
# Prefix cache: a trie over full pages of prompt tokens, with each node's
# path materialized as a chained block hash (hash_i = H(hash_{i-1}, block_i)),
# so lookup is a dict walk — one probe per page — and eviction is O(1).
# ---------------------------------------------------------------------------

_ROOT_HASH = 0


def block_hash(prev: int, tokens: Sequence[int]) -> int:
    """Chained content hash of one full page of tokens: blake2b-64 over the
    parent hash and the token bytes. A strong content hash (vLLM moved the
    same way) keeps collisions — accidental, or deliberate prefix-cache
    poisoning in multi-tenant use — from mapping two different prefixes to
    one trie node and silently serving the wrong KV pages."""
    data = np.asarray(tokens, dtype=np.int64).tobytes()
    h = hashlib.blake2b(prev.to_bytes(8, "little") + data, digest_size=8)
    return int.from_bytes(h.digest(), "little")


class PrefixCache:
    """Maps chained token-block hashes to physical pages whose KV content is
    the attention state of exactly that prompt prefix. Nodes hold *weak*
    references: registering a page does not pin it — when its refcount drops
    to 0 the allocator retires it to the LRU pool instead of freeing, and
    reclaiming it from the LRU drops the node (``allocator.on_evict``)."""

    def __init__(self, allocator: PagedAllocator):
        self.allocator = allocator
        self.page_size = allocator.page_size
        self._nodes: Dict[int, int] = {}       # chain hash -> physical page
        self._page_hash: Dict[int, int] = {}   # physical page -> chain hash
        allocator.on_evict = self._on_evict
        self.hit_pages = 0
        self.miss_pages = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def _on_evict(self, page: int) -> None:
        h = self._page_hash.pop(page, None)
        if h is not None:
            self._nodes.pop(h, None)

    # ---------------- lookup / insert ----------------
    def lookup(self, tokens: Sequence[int], *, record: bool = True) -> List[int]:
        """Physical pages covering the longest cached prefix of full token
        blocks. Descendant pages of a missing node are unreachable by
        construction (their chain hash includes the missing ancestor).

        ``record=False`` probes without touching the hit/miss counters — for
        speculative callers (the scheduler re-probes the head-of-queue
        request every scheduling step) that count via ``record_probe`` only
        when the request is actually admitted."""
        ps = self.page_size
        pages: List[int] = []
        h = _ROOT_HASH
        n_blocks = len(tokens) // ps
        for b in range(n_blocks):
            h = block_hash(h, tokens[b * ps:(b + 1) * ps])
            page = self._nodes.get(h)
            if page is None:
                break
            pages.append(page)
        if record:
            self.hit_pages += len(pages)
            self.miss_pages += n_blocks - len(pages)
        return pages

    def record_probe(self, n_tokens: int, hit_pages: int) -> None:
        """Count one admitted request's probe outcome toward the hit-rate
        stats (pairs with ``lookup(..., record=False)``)."""
        self.hit_pages += hit_pages
        self.miss_pages += max(n_tokens // self.page_size - hit_pages, 0)

    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               n_blocks: int) -> int:
        """Register the first ``n_blocks`` full pages of ``tokens`` as cached
        content held in ``pages`` (the owning slot's physical pages, in
        logical order). Existing nodes win — a second slot that prefilled the
        same prefix concurrently keeps its pages private. Returns the number
        of nodes added."""
        ps = self.page_size
        added = 0
        h = _ROOT_HASH
        for b in range(min(n_blocks, len(pages), len(tokens) // ps)):
            h = block_hash(h, tokens[b * ps:(b + 1) * ps])
            if h in self._nodes:
                continue
            page = pages[b]
            if page in self._page_hash:        # page already backs another node
                continue
            self._nodes[h] = page
            self._page_hash[page] = h
            self.allocator.mark_cached(page)
            added += 1
        return added

    def drop(self, page: int) -> None:
        """Explicitly unregister a page (testing / manual invalidation)."""
        self._on_evict(page)
        self.allocator.unmark_cached(page)

    def hit_rate(self) -> float:
        total = self.hit_pages + self.miss_pages
        return self.hit_pages / total if total else 0.0
