# The paper's primary contribution: the end-to-end serving system
# (gateway + router + replicas + continuous-batching engine + paged KV).
from repro.core.engine import EngineConfig, InferenceEngine, TokenEvent
from repro.core.faults import FaultEvent, FaultInjector, FaultPlan, TransientSubmitError
from repro.core.gateway import Gateway, GatewayConfig, baseline_gateway_config, scale_gateway_config
from repro.core.kv_cache import OutOfPages, PagedAllocator, PrefixCache
from repro.core.metrics import BenchmarkSummary, Request, now, request_metrics, summarize
from repro.core.observability import MetricsSink, Span, Tracer
from repro.core.replica import Replica
from repro.core.router import FailoverEvent, NoReplicaAvailable, ReplicaRouter, RouterConfig
from repro.core.scheduler import ContinuousBatchScheduler
from repro.core.serde import CODECS
from repro.core.spec import PromptLookupDraft, target_probs, verify_draft
from repro.core.timeline import LogHistogram, SLOConfig, StepRecord, TimelineAggregator

__all__ = [
    "EngineConfig", "InferenceEngine", "TokenEvent",
    "FaultEvent", "FaultInjector", "FaultPlan", "TransientSubmitError",
    "FailoverEvent",
    "Gateway", "GatewayConfig", "baseline_gateway_config", "scale_gateway_config",
    "OutOfPages", "PagedAllocator", "PrefixCache", "BenchmarkSummary",
    "Request", "now",
    "request_metrics", "summarize", "MetricsSink", "Replica",
    "NoReplicaAvailable", "ReplicaRouter", "RouterConfig",
    "ContinuousBatchScheduler", "CODECS",
    "PromptLookupDraft", "target_probs", "verify_draft",
    "Span", "Tracer", "LogHistogram", "SLOConfig", "StepRecord",
    "TimelineAggregator",
]
