"""Speculative decoding on the chunk path (DESIGN.md §3).

Two pieces live here, both free of engine state:

  PromptLookupDraft   the draft source: prompt-lookup (n-gram) drafting
                      (Saxena's assisted-generation trick, used by vLLM's
                      ``speculative_model="[ngram]"``). A slot's recent
                      token suffix is matched against its own prompt+output
                      history; the tokens that followed the most recent
                      earlier occurrence become the draft. No second model,
                      no extra weights — ideal for extractive (RAG-style)
                      traffic where the model copies spans of the prompt.

  verify_draft        the accept/reject rule applied to the target model's
                      chunk logits (``LM.decode_chunk(all_logits=True)``
                      scores all K+1 fed tokens in one step). Greedy
                      requests accept a draft token iff it equals the
                      argmax — output is bit-identical to non-speculative
                      decoding. Sampled requests use rejection sampling
                      against the temperature/top-p target distribution:
                      a draft token x (point-mass proposal) is accepted
                      with probability p(x); on rejection the replacement
                      is drawn from the residual p with x removed and
                      renormalized — the committed stream is distributed
                      exactly as non-speculative sampling (Leviathan et
                      al. 2211.17192, specialized to a deterministic
                      proposal).

Everything here is pure: the engine owns KV rollback and page accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass
class PromptLookupDraft:
    """Prompt-lookup n-gram drafting over a slot's token history.

    ``propose`` tries suffix n-grams from ``ngram_max`` down to
    ``ngram_min``; the first n with an earlier occurrence wins, and the
    (up to k) tokens following its most recent occurrence are the draft.
    Returns [] when nothing matches — the slot decodes normally.
    """
    ngram_max: int = 3
    ngram_min: int = 1

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        L = len(tokens)
        if k <= 0 or L < self.ngram_min + 1:
            return []
        toks = tokens if isinstance(tokens, list) else [int(t) for t in tokens]
        for n in range(min(self.ngram_max, L - 1), self.ngram_min - 1, -1):
            suffix = toks[L - n:]
            # scan most-recent-first for an earlier occurrence ending
            # strictly before the final suffix; start <= L-n-1 guarantees at
            # least one continuation token. A backward scan is O(1) on the
            # common case (repetitive history matches near the tail) where a
            # vectorized all-positions match would pay O(L*n) every call.
            for start in range(L - n - 1, -1, -1):
                if toks[start:start + n] == suffix:
                    draft = toks[start + n:start + n + k]
                    # a match close to the tail implies a cycle of period
                    # L - n - start; extend the short continuation
                    # periodically so runs ("x x x") and short cycles fill
                    # the full k-token draft instead of 1-2 tokens
                    period = L - n - start
                    while len(draft) < k:
                        draft.append(draft[len(draft) - period])
                    return draft
        return []


def target_probs(logits, temperature: float, top_p: float):
    """The engine's sampling distribution as explicit probabilities:
    temperature-scaled softmax truncated to the top-p nucleus and
    renormalized (the first token of the sorted order is always kept,
    mirroring ``sample_tokens``). logits (..., V) -> probs (..., V) f32."""
    scaled = logits.astype(jnp.float32) / temperature
    sl, si = jax.lax.top_k(scaled, scaled.shape[-1])         # descending
    p = jax.nn.softmax(sl, axis=-1)
    keep = (jnp.cumsum(p, axis=-1) - p) < top_p
    p_kept = jax.nn.softmax(jnp.where(keep, sl, -jnp.inf), axis=-1)
    inv = jnp.argsort(si, axis=-1)                           # back to vocab order
    return jnp.take_along_axis(p_kept, inv, axis=-1)


def verify_draft(logits, tokens, nvalid, key, temperature: float,
                 top_p: float, greedy: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Accept/reject one speculative chunk per row.

    logits (M, C, V): decode_chunk(all_logits=True) output for rows that fed
    ``tokens`` (M, C) = [last_token, d_1 .. d_K] (C = 1 + K); row position j
    scores the token at index j+1. nvalid (M,): tokens actually fed per row
    (1 + k_i; 0 = inactive row).

    Returns (n_acc (M,), out (M,)): the length of the accepted draft prefix
    and the token to commit after it — the bonus token when every draft was
    accepted, the greedy/residual-sampled correction otherwise. Committing
    d_1..d_{n_acc} then ``out`` reproduces the non-speculative engine
    exactly (bit-identical for greedy, in distribution for sampling).
    """
    M, C, _ = logits.shape
    K = C - 1
    drafts = tokens[:, 1:].astype(jnp.int32)                 # (M, K)
    valid = jnp.arange(K)[None, :] < (nvalid[:, None] - 1)
    k_acc, k_out = jax.random.split(key)
    det = greedy or temperature <= 0.0
    if det:
        pred = jnp.argmax(logits[:, :K], axis=-1).astype(jnp.int32)
        acc = (pred == drafts) & valid
    else:
        p = target_probs(logits[:, :K], temperature, top_p)  # (M, K, V)
        p_draft = jnp.take_along_axis(p, drafts[..., None], axis=-1)[..., 0]
        u = jax.random.uniform(k_acc, (M, K))
        acc = (u < p_draft) & valid                          # q is a point mass
    n_acc = jnp.cumprod(acc.astype(jnp.int32), axis=1).sum(axis=1)

    sel = jnp.take_along_axis(logits, n_acc[:, None, None], axis=1)[:, 0]
    if det:
        out = jnp.argmax(sel, axis=-1).astype(jnp.int32)
    else:
        p_sel = target_probs(sel, temperature, top_p)        # (M, V)
        rejected = jnp.take_along_axis(
            tokens.astype(jnp.int32), jnp.minimum(n_acc + 1, C - 1)[:, None],
            axis=1)[:, 0]
        had_reject = n_acc < (nvalid - 1)
        hit = jnp.arange(p_sel.shape[-1])[None, :] == rejected[:, None]
        p_res = jnp.where(hit & had_reject[:, None], 0.0, p_sel)
        # gumbel-argmax over the unnormalized residual == categorical over
        # the renormalized residual; a rejected token with p(x) == 1 cannot
        # reach here (its rejection probability is 0)
        g = jax.random.gumbel(k_out, p_res.shape)
        out = jnp.argmax(jnp.where(p_res > 0, jnp.log(p_res), -jnp.inf) + g,
                         axis=-1).astype(jnp.int32)
    return n_acc, out
