"""Windowed serving timeline + SLO attainment (DESIGN.md §4).

Turns two raw streams into the dashboard panel vocabulary (the ROADMAP
item-3 referee: p50/p99 TTFT and TBT, queue depth/time, throughput,
utilization, preemption and eviction rates):

  * ``StepRecord`` — one row per engine iteration (``InferenceEngine``
    keeps them in a bounded ring buffer): what was packed against the
    token budget, batch occupancy, queue depth, KV page pressure, spec
    acceptance, wall time.
  * completed ``Request`` objects — per-request latency metrics
    (``request_metrics``) bucketed by completion time, each judged
    against configurable TTFT/TBT SLO targets.

Percentiles come from log-bucketed histograms (geometric buckets, sparse
dict storage, no dependencies) so a window costs O(observations) to build
and O(buckets) to summarize, with bounded relative error (one bucket
width, ~9% at the default growth factor).
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.metrics import Request, request_metrics


@dataclass
class StepRecord:
    """One engine iteration (``InferenceEngine.step``). Token counts are
    tokens *fed* this step (rejected speculative drafts included — they
    consumed compute); ``preemptions`` / ``cow_pages`` are per-step deltas
    of the engine's cumulative counters."""
    step: int
    t0: float                      # monotonic wall-clock (metrics.now)
    t1: float
    budget: int                    # per-iteration token budget
    tokens_packed: int             # all tokens fed (prefill+decode+drafts)
    n_admitted: int
    prefill_rows: int
    prefill_tokens: int
    decode_rows: int
    decode_tokens: int             # committed decode tokens
    drafted_tokens: int
    accepted_tokens: int
    occupancy: int                 # running slots after the step
    max_slots: int
    queue_depth: int               # waiting requests after the step
    kv_free_pages: int
    kv_total_pages: int
    preemptions: int
    cow_pages: int

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class LogHistogram:
    """Sparse log-bucketed histogram for positive values.

    Bucket ``i`` covers ``[min_value * growth**i, min_value * growth**(i+1))``;
    values below ``min_value`` (including 0) land in a dedicated underflow
    bucket reported as ``min_value``. Percentiles return the geometric
    midpoint of the selected bucket, so relative error is bounded by the
    growth factor (default 1.2 → <10%)."""

    def __init__(self, growth: float = 1.2, min_value: float = 1e-6):
        assert growth > 1.0 and min_value > 0.0
        self.growth = growth
        self.min_value = min_value
        self._log_g = math.log(growth)
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        if value < self.min_value:
            idx = -1
        else:
            idx = int(math.log(value / self.min_value) / self._log_g)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += value
        self.vmin = min(self.vmin, value)
        self.vmax = max(self.vmax, value)

    def merge(self, other: "LogHistogram") -> None:
        assert other.growth == self.growth and other.min_value == self.min_value
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def _bucket_value(self, idx: int) -> float:
        if idx < 0:
            return self.min_value
        return self.min_value * self.growth ** (idx + 0.5)

    def percentile(self, p: float) -> float:
        """p in [0, 100]. Exact at the extremes (tracked min/max); bucket
        geometric midpoint otherwise."""
        if self.count == 0:
            return 0.0
        if p <= 0:
            return self.vmin
        if p >= 100:
            return self.vmax
        rank = p / 100.0 * self.count
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                return min(max(self._bucket_value(idx), self.vmin), self.vmax)
        return self.vmax

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


@dataclass
class SLOConfig:
    """Per-request service-level objectives. A request attains its SLO when
    TTFT (t4 - t0, the paper's formula) and TBT (seconds/token) both meet
    their targets. ``None`` disables that component."""
    ttft_target_s: Optional[float] = 1.0
    tbt_target_s: Optional[float] = 0.1


@dataclass
class _Window:
    ttft: LogHistogram = field(default_factory=LogHistogram)
    tbt: LogHistogram = field(default_factory=LogHistogram)
    queue_wait: LogHistogram = field(default_factory=LogHistogram)
    steps: int = 0
    busy_s: float = 0.0
    tokens: int = 0                 # all tokens fed by the engine
    decode_tokens: int = 0
    prefill_tokens: int = 0
    budget: int = 0                 # sum of per-step budgets
    occupancy_sum: int = 0
    slots_sum: int = 0
    queue_depth_sum: int = 0
    queue_depth_max: int = 0
    kv_used_frac_sum: float = 0.0
    drafted: int = 0
    accepted: int = 0
    preemptions: int = 0
    cow_pages: int = 0
    admitted: int = 0
    completed: int = 0
    completed_tokens: int = 0
    slo_attained: int = 0
    ttft_ok: int = 0
    tbt_ok: int = 0
    # resilience events (DESIGN.md §5), bucketed by occurrence time
    events: Dict[str, int] = field(default_factory=dict)
    failover_latencies: List[float] = field(default_factory=list)


class TimelineAggregator:
    """Buckets step records and request completions into fixed wall-clock
    windows. The time origin is the first timestamp ever added; windows are
    reported relative to it (``t`` seconds). Ingestion is offline-friendly:
    feed it after a run from the engine ring buffers and the finished
    request list — order does not matter."""

    def __init__(self, window_s: float = 1.0, slo: Optional[SLOConfig] = None):
        assert window_s > 0
        self.window_s = window_s
        self.slo = slo or SLOConfig()
        self._origin: Optional[float] = None
        self._windows: Dict[int, _Window] = {}
        self.n_requests = 0
        self.n_steps = 0
        self._ttft_all = LogHistogram()
        self._tbt_all = LogHistogram()
        self._slo_attained = 0

    def _window(self, t: float) -> _Window:
        if self._origin is None:
            self._origin = t
        idx = math.floor((t - self._origin) / self.window_s)
        w = self._windows.get(idx)
        if w is None:
            w = self._windows[idx] = _Window()
        return w

    # --------------------------------------------------------------- ingest
    def add_step(self, rec: StepRecord) -> None:
        w = self._window(rec.t1)
        w.steps += 1
        w.busy_s += max(rec.duration, 0.0)
        w.tokens += rec.tokens_packed
        w.decode_tokens += rec.decode_tokens
        w.prefill_tokens += rec.prefill_tokens
        w.budget += rec.budget
        w.occupancy_sum += rec.occupancy
        w.slots_sum += rec.max_slots
        w.queue_depth_sum += rec.queue_depth
        w.queue_depth_max = max(w.queue_depth_max, rec.queue_depth)
        if rec.kv_total_pages > 0:
            w.kv_used_frac_sum += 1.0 - rec.kv_free_pages / rec.kv_total_pages
        w.drafted += rec.drafted_tokens
        w.accepted += rec.accepted_tokens
        w.preemptions += rec.preemptions
        w.cow_pages += rec.cow_pages
        w.admitted += rec.n_admitted
        self.n_steps += 1

    def add_steps(self, records) -> None:
        for rec in records:
            self.add_step(rec)

    def add_request(self, r: Request) -> None:
        """Bucket a completed request by its completion timestamp (t6 when
        the client saw the tail, else t3). Queue wait is t2 - t1 (arrival at
        the serving stack to engine admission)."""
        m = request_metrics(r)
        t_done = r.t6 if r.t6 > 0 else r.t3
        w = self._window(t_done)
        w.completed += 1
        w.completed_tokens += m.n_tokens
        w.ttft.record(max(m.ttft, 0.0))
        self._ttft_all.record(max(m.ttft, 0.0))
        if m.n_tokens > 1:
            w.tbt.record(max(m.tbt, 0.0))
            self._tbt_all.record(max(m.tbt, 0.0))
        if r.t2 > 0 and r.t1 > 0:
            w.queue_wait.record(max(r.t2 - r.t1, 0.0))
        ttft_ok = (self.slo.ttft_target_s is None
                   or m.ttft <= self.slo.ttft_target_s)
        tbt_ok = (self.slo.tbt_target_s is None or m.n_tokens <= 1
                  or m.tbt <= self.slo.tbt_target_s)
        w.ttft_ok += ttft_ok
        w.tbt_ok += tbt_ok
        attained = ttft_ok and tbt_ok
        w.slo_attained += attained
        self._slo_attained += attained
        self.n_requests += 1

    def add_requests(self, requests) -> None:
        for r in requests:
            self.add_request(r)

    def add_event(self, name: str, t: float, n: int = 1) -> None:
        """Count a resilience event (shed / retry / deadline_exceeded / ...)
        in the window containing ``t``."""
        w = self._window(t)
        w.events[name] = w.events.get(name, 0) + n

    def add_failover(self, t: float, latency_s: float) -> None:
        """One replica failover: counted as an event and its detection
        latency (last heartbeat to detection) kept for the summary."""
        w = self._window(t)
        w.events["failovers"] = w.events.get("failovers", 0) + 1
        w.failover_latencies.append(latency_s)

    # --------------------------------------------------------------- output
    def timeline(self) -> List[Dict[str, Any]]:
        """One dict per non-empty window, time-ordered. Gaps (windows with
        no activity at all) are omitted."""
        out: List[Dict[str, Any]] = []
        ws = self.window_s
        for idx in sorted(self._windows):
            w = self._windows[idx]
            out.append({
                "t": idx * ws,
                "window_s": ws,
                "steps": w.steps,
                "completed": w.completed,
                "admitted": w.admitted,
                "throughput_tok_s": w.tokens / ws,
                "decode_tok_s": w.decode_tokens / ws,
                "prefill_tok_s": w.prefill_tokens / ws,
                "p50_ttft_s": w.ttft.percentile(50),
                "p99_ttft_s": w.ttft.percentile(99),
                "p50_tbt_s": w.tbt.percentile(50),
                "p99_tbt_s": w.tbt.percentile(99),
                "p50_queue_wait_s": w.queue_wait.percentile(50),
                "p99_queue_wait_s": w.queue_wait.percentile(99),
                "queue_depth_mean": w.queue_depth_sum / w.steps if w.steps else 0.0,
                "queue_depth_max": w.queue_depth_max,
                "occupancy_frac": (w.occupancy_sum / w.slots_sum
                                   if w.slots_sum else 0.0),
                "budget_util": w.tokens / w.budget if w.budget else 0.0,
                "kv_util_mean": w.kv_used_frac_sum / w.steps if w.steps else 0.0,
                "busy_frac": min(w.busy_s / ws, 1.0),
                "preemptions_per_s": w.preemptions / ws,
                "cow_pages_per_s": w.cow_pages / ws,
                "spec_acceptance": (w.accepted / w.drafted if w.drafted else 0.0),
                "slo_attainment": (w.slo_attained / w.completed
                                   if w.completed else None),
                "ttft_ok_frac": (w.ttft_ok / w.completed
                                 if w.completed else None),
                "tbt_ok_frac": (w.tbt_ok / w.completed if w.completed else None),
                "shed": w.events.get("shed", 0),
                "retries": w.events.get("retries", 0),
                "deadline_exceeded": w.events.get("deadline_exceeded", 0),
                "failovers": w.events.get("failovers", 0),
            })
        return out

    def summary(self) -> Dict[str, Any]:
        wins = self._windows.values()
        total_tokens = sum(w.tokens for w in wins)
        span_s = len(self._windows) * self.window_s
        return {
            "window_s": self.window_s,
            "n_windows": len(self._windows),
            "n_steps": self.n_steps,
            "n_requests": self.n_requests,
            "slo": asdict(self.slo),
            "slo_attainment": (self._slo_attained / self.n_requests
                               if self.n_requests else None),
            "p50_ttft_s": self._ttft_all.percentile(50),
            "p99_ttft_s": self._ttft_all.percentile(99),
            "p50_tbt_s": self._tbt_all.percentile(50),
            "p99_tbt_s": self._tbt_all.percentile(99),
            "throughput_tok_s": total_tokens / span_s if span_s else 0.0,
            "preemptions": sum(w.preemptions for w in wins),
            "completed_tokens": sum(w.completed_tokens for w in wins),
            "shed": sum(w.events.get("shed", 0) for w in wins),
            "retries": sum(w.events.get("retries", 0) for w in wins),
            "deadline_exceeded": sum(w.events.get("deadline_exceeded", 0)
                                     for w in wins),
            "failovers": sum(w.events.get("failovers", 0) for w in wins),
            "failover_latency_max_s": max(
                (v for w in wins for v in w.failover_latencies), default=0.0),
            "failover_latency_mean_s": (
                (lambda vs: sum(vs) / len(vs) if vs else 0.0)(
                    [v for w in wins for v in w.failover_latencies])),
        }
