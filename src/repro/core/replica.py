"""Replica: one inference engine + its serving thread + health state.

"A replica represents the smallest unit of resource allocation and is
designed to be homogeneous" (paper §3.1). Each replica owns an engine
(optionally with a mesh slice / TP degree on real hardware) and steps it on a
dedicated thread; token events are delivered to per-request callbacks from
that thread (the gateway bridges them into asyncio).

``kill()`` simulates a replica failure: the thread stops and the in-flight
requests (with their partial generations) are returned so the router can
resume them on a healthy replica.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.engine import InferenceEngine, TokenEvent
from repro.core.metrics import Request

OnEvent = Callable[[TokenEvent], None]


class Replica:
    def __init__(self, replica_id: str, engine: InferenceEngine, *,
                 klass: str = "default", tp_degree: int = 1,
                 step_watchdog_s: float = 30.0, injector=None):
        self.replica_id = replica_id
        self.engine = engine
        self.klass = klass                     # blueprint class: "high_tp" | "high_replica" | ...
        self.tp_degree = tp_degree
        self.healthy = True
        self.crashed = False                   # injected crash fired (thread exited)
        self.step_watchdog_s = step_watchdog_s
        # fault injection (DESIGN.md §5): evaluated once per loop iteration;
        # also wired into the engine's per-step hook under this replica's id.
        self.injector = injector
        if injector is not None and hasattr(engine, "injector"):
            engine.injector = injector
            engine.fault_key = replica_id
        self.last_step_at = time.monotonic()
        self._inbox: "queue.Queue[Tuple[Request, OnEvent]]" = queue.Queue()
        self._inflight: Dict[str, Tuple[Request, OnEvent]] = {}
        self._cancel: "queue.Queue[str]" = queue.Queue()
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.total_completed = 0
        # synchronous load counter: incremented at submit() time so the
        # router's least-loaded choice never races the replica thread
        self._outstanding = 0

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "Replica":
        self._thread = threading.Thread(target=self._loop, name=f"replica-{self.replica_id}",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop = True
        self._wake.set()
        if self._thread:
            self._thread.join(timeout=5)

    def kill(self) -> List[Tuple[Request, OnEvent]]:
        """Simulated failure: stop serving, surrender in-flight requests —
        including ones still in the inbox (submitted but not yet moved to the
        engine when the serving thread died), which would otherwise be lost
        until the client times out."""
        self.healthy = False
        self.stop()
        with self._lock:
            orphans = list(self._inflight.values())
            self._inflight.clear()
        while True:
            try:
                orphans.append(self._inbox.get_nowait())
            except queue.Empty:
                break
        # free the dead engine's KV: a crashed replica's allocator must not
        # leak its orphans' pages (the leak check at bench exit covers dead
        # replicas too). getattr-guarded: tests stub the engine.
        cancel = getattr(self.engine, "cancel", None)
        if cancel is not None:
            for req, _ in orphans:
                cancel(req.req_id)
        return orphans

    def thread_dead(self) -> bool:
        """Crash detection: the serving thread exited without being asked to
        stop (injected crash / unhandled exception in the loop)."""
        return (self._thread is not None and not self._thread.is_alive()
                and not self._stop)

    def set_degraded(self, on: bool) -> None:
        """Brown-out toggle from the gateway: disables speculative drafting
        on this replica's engine while overloaded."""
        if hasattr(self.engine, "degraded"):
            self.engine.degraded = on

    # ------------------------------------------------------------- load stats
    def engine_stats(self) -> Dict[str, float]:
        """TokenEvent-level engine counters (prefix cache, COW, eviction) —
        safe to sample from any thread (all cumulative scalars)."""
        return self.engine.stats()

    def step_records(self) -> list:
        """Snapshot of the engine's iteration-profile ring buffer
        (``StepRecord`` rows, oldest first). Safe to call from any thread:
        deque snapshots are atomic under the GIL and records are immutable
        once appended."""
        return list(self.engine.step_records)

    @property
    def load(self) -> int:
        return self._outstanding

    @property
    def active(self) -> int:
        return len(self._inflight)

    # ------------------------------------------------------------- submit
    def submit(self, request: Request, on_event: OnEvent) -> None:
        if not self.healthy:
            raise RuntimeError(f"replica {self.replica_id} is down")
        request.replica_id = self.replica_id
        with self._lock:
            self._outstanding += 1
        self._inbox.put((request, on_event))
        self._wake.set()

    def cancel(self, req_id: str) -> None:
        self._cancel.put(req_id)
        self._wake.set()

    # ------------------------------------------------------------- engine loop
    def _loop(self) -> None:
        while not self._stop:
            if self.injector is not None:
                act = self.injector.replica_action(self.replica_id)
                if act is not None:
                    kind, remaining = act
                    if kind == "crash":
                        # the serving thread exits WITHOUT cleanup: healthy
                        # stays True, inflight/inbox stay populated — exactly
                        # what a real process death looks like. Detection is
                        # the router monitor's job (thread_dead()).
                        self.crashed = True
                        return
                    # stall: frozen loop — no stepping, no inbox drain, no
                    # heartbeat update, so the watchdog fires.
                    time.sleep(min(max(remaining, 0.0), 0.02))
                    continue
            moved = False
            while True:
                try:
                    req, cb = self._inbox.get_nowait()
                except queue.Empty:
                    break
                with self._lock:
                    self._inflight[req.req_id] = (req, cb)
                self.engine.submit(req)
                moved = True
            while True:
                try:
                    rid = self._cancel.get_nowait()
                except queue.Empty:
                    break
                self.engine.cancel(rid)
                with self._lock:
                    if self._inflight.pop(rid, None) is not None:
                        self._outstanding -= 1
                moved = True

            if self.engine.has_work():
                self.last_step_at = time.monotonic()
                for ev in self.engine.step():
                    rid = ev.request.req_id
                    with self._lock:
                        entry = self._inflight.get(rid)
                    if entry is None:
                        continue                        # cancelled mid-step
                    _, cb = entry
                    cb(ev)
                    if ev.finished:
                        with self._lock:
                            if self._inflight.pop(rid, None) is not None:
                                self._outstanding -= 1
                        self.total_completed += 1
            elif not moved:
                self._wake.wait(timeout=0.002)
                self._wake.clear()

    def watchdog_expired(self) -> bool:
        """Straggler detection: the replica has work (in the engine OR stuck
        in an undrained inbox — a stalled loop drains nothing) but hasn't
        stepped lately."""
        return (self.healthy
                and (self.engine.has_work() or not self._inbox.empty())
                and time.monotonic() - self.last_step_at > self.step_watchdog_s)
