"""The inference engine: jitted prefill/decode over fixed batch-slot shapes,
paged KV, continuous batching, temperature/top-p sampling.

Design (TPU-native, runs for real on CPU):
  - decode is ONE jitted function over (max_slots, 1) — slots that are empty
    are masked; no recompilation ever happens during serving.
  - prefill is jitted per power-of-two length bucket (a handful of compiles).
  - prefill fills a fresh dense cache, which is then scattered into the paged
    pool (jitted, donated) — pages for attention KV, slot-indexed pools for
    SSM state / conv state / cross-attention memory.
  - the scheduler's max-utilization policy pauses requests under page
    pressure (see scheduler.py) and the engine re-prefills them on return.

``host_overhead_s`` models engine-runtime software overhead per iteration and
is used ONLY by the benchmark harness to represent baseline engines
(HF/vLLM-class host overhead) — the ScaleLLM engine runs with 0.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_cache import PagedAllocator
from repro.core.metrics import Request, now
from repro.core.scheduler import ContinuousBatchScheduler, SlotState
from repro.models import LM, RunCtx


@dataclass
class EngineConfig:
    max_slots: int = 8
    page_size: int = 16
    num_pages: int = 512
    max_seq: int = 512
    prefill_bucket: int = 32          # min prefill padding bucket
    temperature: float = 0.5
    top_p: float = 0.7
    greedy: bool = False
    scheduler: str = "max_utilization"
    eos_id: int = -1                  # -1: no EOS (length-controlled)
    host_overhead_s: float = 0.0      # baseline-engine emulation knob (benchmarks)
    cache_dtype: Any = jnp.float32
    seed: int = 0

    @property
    def max_pages_per_seq(self) -> int:
        return (self.max_seq + self.page_size - 1) // self.page_size


@dataclass
class TokenEvent:
    request: Request
    token: int
    t_emit: float
    finished: bool


# Module-level jit cache: replicas sharing a model reuse compiled programs
# (a fleet of N replicas compiles once, not N times).
_JIT_CACHE: Dict[Tuple, Callable] = {}


def _cached_jit(kind: str, model, ctx, sampling, builder):
    key = (kind, id(model), ctx, sampling)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = builder()
    return _JIT_CACHE[key]


def sample_tokens(logits, key, temperature: float, top_p: float, greedy: bool):
    """logits (B, V) -> (B,) int32. Nucleus sampling with temperature."""
    if greedy or temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    sl, si = jax.lax.top_k(l, l.shape[-1])                  # descending sort
    p = jax.nn.softmax(sl, axis=-1)
    keep = (jnp.cumsum(p, axis=-1) - p) < top_p             # first always kept
    sl = jnp.where(keep, sl, -jnp.inf)
    g = jax.random.gumbel(key, sl.shape)
    choice = jnp.argmax(sl + g, axis=-1)
    return jnp.take_along_axis(si, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


class InferenceEngine:
    """Single-replica engine. Thread-safety is owned by core.replica."""

    def __init__(self, model: LM, params, cfg: EngineConfig, ctx: Optional[RunCtx] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or RunCtx(attn_backend="xla", moe_strategy="dropless",
                                 block_q=128, block_kv=128)
        self.allocator = PagedAllocator(cfg.num_pages, cfg.page_size, cfg.max_pages_per_seq)
        self.scheduler = ContinuousBatchScheduler(
            cfg.max_slots, self.allocator, policy=cfg.scheduler, max_seq=cfg.max_seq)
        self.cache = model.init_cache(
            cfg.max_slots, cfg.max_seq, cfg.cache_dtype, kind="paged",
            page_size=cfg.page_size, num_pages=cfg.num_pages)
        self.page_table = np.zeros((cfg.max_slots, cfg.max_pages_per_seq), np.int32)
        self.lengths = np.zeros((cfg.max_slots,), np.int32)
        self.last_tokens = np.zeros((cfg.max_slots,), np.int32)
        self.extras: Dict[str, Any] = {}  # frames/patches per slot (encdec/vlm)
        self._key = jax.random.PRNGKey(cfg.seed)
        sampling = (cfg.temperature, cfg.top_p, cfg.greedy, cfg.page_size)
        self._decode_jit = _cached_jit(
            "decode", model, self.ctx, sampling,
            lambda: jax.jit(self._decode_fn, donate_argnums=(1,)))
        self._prefill_jit = _cached_jit(
            "prefill", model, self.ctx, sampling,
            lambda: jax.jit(self._prefill_fn))
        self._scatter_jit = _cached_jit(
            "scatter", model, self.ctx, sampling,
            lambda: jax.jit(self._scatter_fn, donate_argnums=(0,),
                            static_argnames=("slot_pages",)))
        self.steps = 0
        self.decode_tokens = 0

    # ------------------------------------------------------------- jitted fns
    def _decode_fn(self, params, cache, tokens, positions, page_table, lengths, key, active):
        logits, cache = self.model.decode_step(
            params, tokens, cache, positions, self.ctx,
            page_table=page_table, lengths=lengths)
        nxt = sample_tokens(logits, key, self.cfg.temperature, self.cfg.top_p,
                            self.cfg.greedy)
        return jnp.where(active, nxt, 0), cache

    def _prefill_fn(self, params, batch, dense_cache, key, last_pos):
        logits, dense_cache = self.model.prefill(params, batch, dense_cache,
                                                 self.ctx, last_pos=last_pos)
        nxt = sample_tokens(logits, key, self.cfg.temperature, self.cfg.top_p,
                            self.cfg.greedy)
        return nxt, dense_cache

    def _scatter_fn(self, pool, dense, page_ids, slot, *, slot_pages: int):
        """Move a (B=1, Spad) dense prefill cache into the paged pool at
        `slot`. page_ids: (max_pages_per_seq,) physical ids (tail entries 0)."""
        ps = self.cfg.page_size

        def walk(pool_n, dense_n):
            out = {}
            for name, pv in pool_n.items():
                dv = dense_n.get({"kp": "k", "vp": "v"}.get(name, name))
                if isinstance(pv, dict):
                    out[name] = walk(pv, dv)
                elif name in ("kp", "vp"):
                    src = dv[:, 0]                        # (R, W, Hkv, hd)
                    R, W = src.shape[0], src.shape[1]
                    npg = min(W // ps, slot_pages) if W >= ps else 0
                    if npg > 0:
                        blocks = src[:, : npg * ps].reshape(R, npg, ps, *src.shape[2:])
                        out[name] = pv.at[:, page_ids[:npg]].set(blocks.astype(pv.dtype))
                    else:
                        out[name] = pv
                elif name in ("state", "conv", "ck", "cv"):
                    out[name] = pv.at[:, slot].set(dv[:, 0].astype(pv.dtype))
                else:                                     # k/v/slot_pos unused in pool
                    out[name] = pv
            return out

        new_groups = []
        for g_pool, g_dense in zip(pool["groups"], dense["groups"]):
            new_groups.append([walk(pp, dd) for pp, dd in zip(g_pool, g_dense)])
        return {"groups": new_groups}

    # ------------------------------------------------------------- helpers
    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _bucket(self, n: int) -> int:
        b = self.cfg.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.cfg.max_seq)

    def submit(self, request: Request) -> None:
        self.scheduler.add(request)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # ------------------------------------------------------------- prefill
    def _run_prefill(self, st: SlotState) -> Optional[int]:
        """Prefill fed tokens for a slot; returns the first sampled token for
        FRESH requests (None for resumed ones)."""
        resumed = len(st.request.generated) > 0
        feed = st.all_tokens[:-1] if resumed else st.all_tokens
        L = len(feed)
        Lp = self._bucket(L)
        toks = np.zeros((1, Lp), np.int32)
        toks[0, :L] = feed
        batch = {"tokens": jnp.asarray(toks)}
        cfgm = self.model.cfg
        if cfgm.encoder is not None:
            batch["frames"] = self.extras.get(
                (st.request.req_id, "frames"),
                jnp.zeros((1, cfgm.encoder.cross_attn_memory, cfgm.d_model), jnp.float32))
        if cfgm.vision is not None:
            batch["patches"] = self.extras.get(
                (st.request.req_id, "patches"),
                jnp.zeros((1, cfgm.vision.n_patches, cfgm.vision.d_patch), jnp.float32))

        dense = self.model.init_cache(
            1, Lp, self.cfg.cache_dtype, kind="dense",
            memory_len=cfgm.encoder.cross_attn_memory if cfgm.encoder else 0)
        nxt, dense = self._prefill_jit(self.params, batch, dense, self._next_key(),
                                       jnp.asarray([L - 1], jnp.int32))

        # KV for positions >= L in the padded prefill is garbage, but pages
        # only cover ceil(L/ps); attention masks by `lengths`, so it is inert.
        self.allocator.allocate(st.slot, L)
        row = self.allocator.page_table_row(st.slot)
        self.page_table[st.slot] = row
        n_pages = self.allocator.pages_needed(L)
        self.cache = self._scatter_jit(self.cache, dense, jnp.asarray(row),
                                       st.slot, slot_pages=n_pages)
        self.lengths[st.slot] = L
        st.fed = L
        if resumed:
            st.last_token = st.all_tokens[-1]
            return None
        tok = int(nxt[0])
        st.last_token = tok
        st.all_tokens.append(tok)
        return tok

    # ------------------------------------------------------------- step
    def step(self) -> List[TokenEvent]:
        """One engine iteration: admissions (prefill) + one decode sweep."""
        cfg = self.cfg
        events: List[TokenEvent] = []
        if cfg.host_overhead_s > 0:
            time.sleep(cfg.host_overhead_s)
        self.steps += 1

        # ---- admissions
        for st in self.scheduler.schedule().admit:
            r = st.request
            if r.t2 == 0.0:
                r.t2 = now()
            st.admitted_at = now()
            tok = self._run_prefill(st)
            if tok is not None:
                r.generated.append(tok)
                fin = self._check_finished(st, tok)
                events.append(TokenEvent(r, tok, now(), fin))
                if fin:
                    self._finish(st)

        # ---- decode sweep
        active_slots = [s for s, st in self.scheduler.running.items() if st.fed > 0]
        if not active_slots:
            return events
        for s in list(active_slots):
            if s not in self.scheduler.running:            # preempted by an earlier grow
                active_slots.remove(s)
                continue
            if not self.scheduler.grow_for_decode(s):
                active_slots.remove(s)                     # paused/unschedulable
                continue
            self.page_table[s] = self.allocator.page_table_row(s)
        # preemption may have removed slots
        active_slots = [s for s in active_slots if s in self.scheduler.running]
        if not active_slots:
            return events

        M = cfg.max_slots
        # inactive slots must point at the reserved null page 0: the jitted
        # decode writes KV for every slot, and a stale row would corrupt pages
        # that have been freed and reallocated to another sequence.
        for s in range(M):
            if s not in self.scheduler.running:
                self.page_table[s] = 0
        tokens = np.zeros((M, 1), np.int32)
        positions = np.zeros((M,), np.int32)
        active = np.zeros((M,), bool)
        for s in active_slots:
            st = self.scheduler.running[s]
            tokens[s, 0] = st.last_token
            positions[s] = st.fed
            active[s] = True
        lengths = jnp.asarray(np.where(active, positions + 1, np.maximum(self.lengths, 1)).astype(np.int32))
        nxt, self.cache = self._decode_jit(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(self.page_table), lengths, self._next_key(), jnp.asarray(active))
        nxt = np.asarray(nxt)
        t_emit = now()
        self.decode_tokens += len(active_slots)

        for s in active_slots:
            st = self.scheduler.running[s]
            st.fed += 1
            self.lengths[s] = st.fed
            tok = int(nxt[s])
            st.last_token = tok
            st.all_tokens.append(tok)
            st.request.generated.append(tok)
            fin = self._check_finished(st, tok)
            events.append(TokenEvent(st.request, tok, t_emit, fin))
            if fin:
                self._finish(st)
        return events

    def _check_finished(self, st: SlotState, tok: int) -> bool:
        r = st.request
        if len(r.generated) >= r.max_new_tokens:
            return True
        if self.cfg.eos_id >= 0 and tok == self.cfg.eos_id:
            return True
        if st.fed + 1 >= self.cfg.max_seq:
            return True
        return False

    def _finish(self, st: SlotState) -> None:
        st.request.finished = True
        st.request.t3 = now()
        self.scheduler.finish(st.slot)
        self.lengths[st.slot] = 0

    def cancel(self, req_id: str) -> bool:
        """Drop a request (hedging loser / client disconnect). Frees its slot."""
        for i, r in enumerate(self.scheduler.waiting):
            if r.req_id == req_id:
                del self.scheduler.waiting[i]
                return True
        for slot, st in list(self.scheduler.running.items()):
            if st.request.req_id == req_id:
                self.scheduler.finish(slot)
                self.lengths[slot] = 0
                self.page_table[slot] = 0
                return True
        return False

    # ------------------------------------------------------------- sync api
    def generate(self, requests: List[Request], max_steps: int = 100_000) -> List[Request]:
        """Blocking helper for tests/benchmarks without the gateway stack."""
        for r in requests:
            r.t0 = r.t0 or now()
            r.t1 = r.t1 or now()
            self.submit(r)
        steps = 0
        while self.has_work() and steps < max_steps:
            for ev in self.step():
                if ev.request.t4 == 0.0:
                    ev.request.t4 = ev.t_emit
                    ev.request.t5 = now()
                if ev.finished:
                    ev.request.t6 = now()
            steps += 1
        return requests
