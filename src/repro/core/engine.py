"""The inference engine: one jitted chunked iteration over fixed shapes,
paged KV, continuous batching with a per-iteration token budget,
temperature/top-p sampling.

Design (TPU-native, runs for real on CPU; see DESIGN.md §2):
  - prefill and decode are ONE model path (``LM.decode_chunk``): every batch
    row feeds a chunk of tokens of one sequence whose KV is written straight
    into the paged pool. Decode is a chunk of 1.
  - two fixed call shapes, each compiled once: (chunk_rows, prefill_chunk)
    for the prefill pack and (max_slots, 1) for the decode sweep. There is
    no per-length bucket recompile ladder, no dense per-request prefill
    cache, and no post-prefill scatter copy.
  - each ``step()`` is a token-budget iteration (Sarathi-style): all pending
    decode tokens plus up to ``token_budget - n_decode`` prefill-chunk
    tokens. Long prompts prefill over several iterations, so an admitted
    prompt never head-of-line blocks running decodes.
  - the scheduler's max-utilization policy pauses requests under page
    pressure (see scheduler.py); a paused, partially-prefilled slot resumes
    from chunk 0 with its generated tokens intact.

``host_overhead_s`` models engine-runtime software overhead per iteration and
is used ONLY by the benchmark harness to represent baseline engines
(HF/vLLM-class host overhead) — the ScaleLLM engine runs with 0.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_cache import PagedAllocator, PrefixCache
from repro.core.metrics import Request, now
from repro.core.observability import Tracer
from repro.core.scheduler import ContinuousBatchScheduler, SlotState
from repro.core.spec import PromptLookupDraft, verify_draft
from repro.core.timeline import StepRecord
from repro.models import LM, RunCtx

# fixed operand width of the jitted COW page-copy call (pads with 0->0
# null-page self-copies) so repeated copies never retrace
COW_BUF = 8


@dataclass
class EngineConfig:
    max_slots: int = 8
    page_size: int = 16
    num_pages: int = 512
    max_seq: int = 512
    prefill_bucket: int = 32          # legacy knob: default for prefill_chunk
    prefill_chunk: int = 0            # chunked-prefill size (0: prefill_bucket)
    token_budget: int = 0             # per-iteration token cap (0: slots+2*chunk)
    temperature: float = 0.5
    top_p: float = 0.7
    greedy: bool = False
    scheduler: str = "max_utilization"
    enable_prefix_cache: bool = True  # shared-prefix KV reuse (auto-off for
                                      # ssm/encdec/vlm: pages alone don't
                                      # capture their recurrent/cross state)
    enable_speculative: bool = False  # prompt-lookup drafting + multi-token
                                      # verify on the chunk path (auto-off
                                      # for ssm/hybrid: conv + recurrent
                                      # carry advance on every fed token and
                                      # cannot be rolled back per position)
    spec_k: int = 4                   # max draft tokens per slot per step
    spec_ngram_max: int = 3           # prompt-lookup suffix n-gram bounds
    spec_ngram_min: int = 1
    eos_id: int = -1                  # -1: no EOS (length-controlled)
    host_overhead_s: float = 0.0      # baseline-engine emulation knob (benchmarks)
    profile_steps: bool = True        # keep one StepRecord per iteration in a
                                      # bounded ring (cheap: a dataclass + a
                                      # dozen counter reads per device call)
    profile_fence: bool = False       # block_until_ready before timestamping
                                      # each step (true device wall time; off
                                      # by default — it serializes dispatch)
    step_records_cap: int = 4096      # ring-buffer capacity for step records
    cache_dtype: Any = jnp.float32
    seed: int = 0

    @property
    def max_pages_per_seq(self) -> int:
        return (self.max_seq + self.page_size - 1) // self.page_size


@dataclass
class TokenEvent:
    request: Request
    token: int                 # -1: terminal no-token event (rejected request)
    t_emit: float
    finished: bool


# Module-level jit cache: replicas sharing a model reuse compiled programs
# (a fleet of N replicas compiles once, not N times). jax.jit retraces per
# call shape, so the two fixed shapes (chunk pack / decode sweep) coexist in
# one entry.
_JIT_CACHE: Dict[Tuple, Callable] = {}


def _cached_jit(kind: str, model, ctx, sampling, builder):
    key = (kind, id(model), ctx, sampling)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = builder()
    return _JIT_CACHE[key]


def sample_tokens(logits, key, temperature: float, top_p: float, greedy: bool):
    """logits (B, V) -> (B,) int32. Nucleus sampling with temperature."""
    if greedy or temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    sl, si = jax.lax.top_k(scaled, scaled.shape[-1])                  # descending sort
    p = jax.nn.softmax(sl, axis=-1)
    keep = (jnp.cumsum(p, axis=-1) - p) < top_p             # first always kept
    sl = jnp.where(keep, sl, -jnp.inf)
    g = jax.random.gumbel(key, sl.shape)
    choice = jnp.argmax(sl + g, axis=-1)
    return jnp.take_along_axis(si, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


class InferenceEngine:
    """Single-replica engine. Thread-safety is owned by core.replica."""

    def __init__(self, model: LM, params, cfg: EngineConfig, ctx: Optional[RunCtx] = None,
                 tracer: Optional[Tracer] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.tracer = tracer
        self.ctx = ctx or RunCtx(attn_backend="xla", moe_strategy="dropless",
                                 block_q=128, block_kv=128)
        cfgm = model.cfg
        self.pos_offset = cfgm.vision.n_patches if cfgm.vision is not None else 0
        self.chunk = min(cfg.prefill_chunk or max(cfg.prefill_bucket, 1), cfg.max_seq)
        self.token_budget = max(cfg.token_budget or (cfg.max_slots + 2 * self.chunk),
                                cfg.max_slots + 1)
        self.chunk_rows = max(1, min(self.token_budget // self.chunk, cfg.max_slots))
        self.allocator = PagedAllocator(cfg.num_pages, cfg.page_size, cfg.max_pages_per_seq)
        # prefix sharing is only sound when a page fully captures a token
        # range's state: SSM carries recurrent state, encdec carries cross-KV,
        # and VLM patch prefixes shift kv positions — all gated off.
        has_ssm = any("M" in g.pattern for g in cfgm.layer_groups)
        prefix_ok = (cfg.enable_prefix_cache and not has_ssm
                     and cfgm.encoder is None and cfgm.vision is None)
        self.prefix_cache = PrefixCache(self.allocator) if prefix_ok else None
        # speculative decoding rolls KV back by a pure length decrement —
        # sound for paged attention (pages are append-only and masked by
        # ``lengths``), unsound for SSM/hybrid conv + recurrent carry.
        self.spec_on = cfg.enable_speculative and cfg.spec_k > 0 and not has_ssm
        self.spec_kmax = cfg.spec_k
        self.draft_source = (PromptLookupDraft(cfg.spec_ngram_max, cfg.spec_ngram_min)
                             if self.spec_on else None)
        self.scheduler = ContinuousBatchScheduler(
            cfg.max_slots, self.allocator, policy=cfg.scheduler, max_seq=cfg.max_seq,
            kv_extra=self.pos_offset, prefix_cache=self.prefix_cache,
            tracer=tracer)
        self.cache = model.init_cache(
            cfg.max_slots, cfg.max_seq, cfg.cache_dtype, kind="paged",
            page_size=cfg.page_size, num_pages=cfg.num_pages)
        self.page_table = np.zeros((cfg.max_slots, cfg.max_pages_per_seq), np.int32)
        self.extras: Dict[str, Any] = {}  # frames/patches per request (encdec/vlm)
        self._key = jax.random.PRNGKey(cfg.seed)
        sampling = (cfg.temperature, cfg.top_p, cfg.greedy, cfg.page_size)
        self._step_jit = _cached_jit(
            "step", model, self.ctx, sampling,
            lambda: jax.jit(self._step_fn, donate_argnums=(1,)))
        self._cow_jit = _cached_jit(
            "cow", model, self.ctx, sampling,
            lambda: jax.jit(self._copy_pages_fn, donate_argnums=(0,)))
        # spec-sweep width ladder: one compiled variant per chunk width
        # C = 1 + k for k in {1, 2, 4, ..., kmax}. The sweep picks the
        # smallest width covering the iteration's longest draft, so compute
        # (which scales with M*C regardless of how many rows carry drafts)
        # tracks actual draft volume instead of always paying 1 + kmax.
        self._spec_widths: List[int] = []
        if self.spec_on:
            k = 1
            while k < self.spec_kmax:
                self._spec_widths.append(1 + k)
                k *= 2
            self._spec_widths.append(1 + self.spec_kmax)
        self._sampling = sampling
        # resilience hooks (DESIGN.md §5): a FaultInjector evaluated at the
        # top of every step (None costs one attribute check), the replica id
        # it matches fault events against, and the brown-out flag that
        # disables speculative drafting while degraded.
        self.injector = None
        self.fault_key: Optional[str] = None
        self.degraded = False
        self.steps = 0
        self.decode_tokens = 0
        self.prefill_tokens = 0
        self.deadline_exceeded = 0        # requests cancelled past deadline
        self.spec_steps = 0               # iterations that ran the verify sweep
        self.drafted_tokens = 0           # draft tokens fed through verify
        self.accepted_tokens = 0          # draft tokens accepted (committed)
        self.prefix_cached_tokens = 0     # prefill tokens skipped via cache hits
        self.iter_token_counts: deque = deque(maxlen=4096)
        # iteration profiler: one StepRecord per step() in a bounded ring
        # (DESIGN.md §4); per-step row counts set by _step as it packs
        self.step_records: deque = deque(maxlen=cfg.step_records_cap)
        self._last_admitted = 0
        self._last_prefill_rows = 0
        self._last_decode_rows = 0

    # ------------------------------------------------------------- jitted fn
    def _step_fn(self, params, cache, tokens, starts, nvalid, slots, first,
                 page_table, key, frames=None, patches=None):
        """One fused iteration over a packed batch of per-sequence chunks
        (decode == chunk of 1). Returns (next_token (B,), cache)."""
        logits, cache = self.model.decode_chunk(
            params, tokens, cache, starts, nvalid, slots, first, self.ctx,
            page_table, frames=frames, patches=patches)
        nxt = sample_tokens(logits, key, self.cfg.temperature, self.cfg.top_p,
                            self.cfg.greedy)
        return jnp.where(nvalid > 0, nxt, 0), cache

    def _spec_fn(self, params, cache, tokens, starts, nvalid, slots, first,
                 page_table, key):
        """Speculative decode sweep (DESIGN.md §3): every row feeds
        [last_token, d_1 .. d_k] in one chunk, the head scores all fed
        positions, and verify_draft turns the logits into (accepted-prefix
        length, next committed token) per row."""
        logits, cache = self.model.decode_chunk(
            params, tokens, cache, starts, nvalid, slots, first, self.ctx,
            page_table, all_logits=True)
        n_acc, out = verify_draft(logits, tokens, nvalid, key,
                                  self.cfg.temperature, self.cfg.top_p,
                                  self.cfg.greedy)
        return n_acc, jnp.where(nvalid > 0, out, 0), cache

    def _spec_jit_for(self, width: int):
        """Compiled spec sweep for chunk width C = ``width`` (lazy, cached
        process-wide like the step fn — one entry per ladder width)."""
        return _cached_jit(
            f"spec{width}", self.model, self.ctx, self._sampling,
            lambda: jax.jit(self._spec_fn, donate_argnums=(1,)))

    def _copy_pages_fn(self, cache, src, dst):
        """Device-side page copy (the COW step): kp/vp[:, dst] = kp/vp[:, src]
        across every attention layer, in one fused call. Padding entries are
        0->0 null-page self-copies (inert)."""
        def walk(c):
            if isinstance(c, dict):
                return {k: (v.at[:, dst].set(v[:, src]) if k in ("kp", "vp")
                            else walk(v)) for k, v in c.items()}
            if isinstance(c, (list, tuple)):
                return type(c)(walk(x) for x in c)
            return c
        return walk(cache)

    def _apply_copies(self, copies: List[Tuple[int, int]]) -> None:
        """Run queued COW page copies before the write that needed them.
        Copies are applied in order; a batch holds at most one copy per
        destination page so the gather-then-scatter semantics of a single
        call can never race two writes to one page."""
        while copies:
            batch, rest, seen = [], [], set()
            for s, d in copies:
                (rest if d in seen else batch).append((s, d))
                seen.add(d)
            for i in range(0, len(batch), COW_BUF):
                sub = batch[i:i + COW_BUF]
                src = np.zeros(COW_BUF, np.int32)
                dst = np.zeros(COW_BUF, np.int32)
                for j, (s, d) in enumerate(sub):
                    src[j], dst[j] = s, d
                self.cache = self._cow_jit(self.cache, jnp.asarray(src),
                                           jnp.asarray(dst))
            copies = rest

    def _register_prefix(self, st: SlotState) -> None:
        """Insert the slot's newly completed full prompt pages into the
        prefix trie (content is final once fed: later writes to shared or
        cached pages always go through COW)."""
        if self.prefix_cache is None:
            return
        nb = min(st.fed, len(st.request.prompt_tokens)) // self.cfg.page_size
        if nb > st.registered_blocks:
            self.prefix_cache.insert(st.all_tokens,
                                     self.allocator.owned(st.slot), nb)
            st.registered_blocks = nb

    # ------------------------------------------------------------- helpers
    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def submit(self, request: Request) -> None:
        self.scheduler.add(request)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def _row_extras(self, grants, B: int):
        """Per-row encoder frames / vision patches operands for a chunk call
        (fixed shapes; rows without extras get zeros, like the legacy dense
        prefill did)."""
        cfgm = self.model.cfg
        frames = patches = None
        if cfgm.encoder is not None:
            M = cfgm.encoder.cross_attn_memory
            fr = np.zeros((B, M, cfgm.d_model), np.float32)
            for i, (st, _) in enumerate(grants):
                v = self.extras.get((st.request.req_id, "frames"))
                if v is not None:
                    v = np.asarray(v)[0]
                    m = min(M, v.shape[0])
                    fr[i, :m] = v[:m]
            frames = jnp.asarray(fr)
        if cfgm.vision is not None:
            Np, Dp = cfgm.vision.n_patches, cfgm.vision.d_patch
            pa = np.zeros((B, Np, Dp), np.float32)
            for i, (st, _) in enumerate(grants):
                v = self.extras.get((st.request.req_id, "patches"))
                if v is not None:
                    pa[i] = np.asarray(v)[0]
            patches = jnp.asarray(pa)
        return frames, patches

    # ------------------------------------------------------------- step
    def step(self) -> List[TokenEvent]:
        """One token-budget iteration: admissions, the prefill chunk pack,
        then one decode sweep — at most ``token_budget`` tokens total.
        With ``profile_steps`` each iteration leaves one :class:`StepRecord`
        in the ``step_records`` ring buffer."""
        if self.injector is not None:
            self.injector.on_engine_step(self)
        if not self.cfg.profile_steps:
            return self._step()
        t0 = now()
        preempt0 = self.scheduler.n_preemptions
        cow0 = self.allocator.cow_copies
        prefill0, decode0 = self.prefill_tokens, self.decode_tokens
        drafted0, accepted0 = self.drafted_tokens, self.accepted_tokens
        events = self._step()
        if self.cfg.profile_fence:
            jax.block_until_ready(self.cache)
        alloc = self.allocator
        self.step_records.append(StepRecord(
            step=self.steps, t0=t0, t1=now(), budget=self.token_budget,
            tokens_packed=self.iter_token_counts[-1] if self.iter_token_counts else 0,
            n_admitted=self._last_admitted,
            prefill_rows=self._last_prefill_rows,
            prefill_tokens=self.prefill_tokens - prefill0,
            decode_rows=self._last_decode_rows,
            decode_tokens=self.decode_tokens - decode0,
            drafted_tokens=self.drafted_tokens - drafted0,
            accepted_tokens=self.accepted_tokens - accepted0,
            occupancy=len(self.scheduler.running),
            max_slots=self.cfg.max_slots,
            queue_depth=len(self.scheduler.waiting),
            kv_free_pages=alloc.free_pages,
            kv_total_pages=alloc.num_pages - 1,   # page 0 is the null page
            preemptions=self.scheduler.n_preemptions - preempt0,
            cow_pages=alloc.cow_copies - cow0))
        return events

    def _step(self) -> List[TokenEvent]:
        cfg = self.cfg
        tr = self.tracer
        events: List[TokenEvent] = []
        if cfg.host_overhead_s > 0:
            time.sleep(cfg.host_overhead_s)
        self.steps += 1
        iter_tokens = 0
        self._last_admitted = self._last_prefill_rows = self._last_decode_rows = 0

        # deadline sweep (DESIGN.md §5): cancel requests past their absolute
        # cutoff before planning, so an expired request provably frees its
        # pages this iteration and never consumes budget again. The terminal
        # event carries error="deadline_exceeded" to the gateway/client.
        for slot, req in self.scheduler.expire_deadlines(now()):
            if slot is not None:
                self.page_table[slot] = 0
            self._drop_extras(req.req_id)
            t_exp = now()
            req.error = "deadline_exceeded"
            req.finished = True
            req.t3 = req.t3 or t_exp
            self.deadline_exceeded += 1
            if tr:
                tr.event(req.req_id, "deadline_exceeded", slot=slot)
            events.append(TokenEvent(req, -1, t_exp, True))

        plan = self.scheduler.plan_iteration(self.token_budget, self.chunk,
                                             self.chunk_rows)
        self._last_admitted = len(plan.admit)
        for st in plan.admit:
            r = st.request
            if r.t2 == 0.0:
                r.t2 = now()
            st.admitted_at = now()
            st.spec_k = self.spec_kmax if self.spec_on else 0
            self.prefix_cached_tokens += st.cached_tokens
            if tr:
                tr.end(r.req_id, "queue", cached_tokens=st.cached_tokens,
                       resumed=bool(r.generated))
            if st.feed_len + self.pos_offset >= cfg.max_seq:
                # prompt can never fit max_seq: fail fast with zero tokens
                # instead of spinning on page growth that cannot succeed.
                # The terminal event is what tells replica/gateway consumers
                # the request is over — without it they leak capacity.
                self._finish(st)
                events.append(TokenEvent(r, -1, now(), True))

        # ---- prefill chunk pack: grow pages, detach shared pages (COW),
        # then one fixed-shape call
        grants: List[Tuple[SlotState, int]] = []
        copies: List[Tuple[int, int]] = []
        for st, n in plan.prefill:
            if st.slot not in self.scheduler.running:      # preempted by an earlier grow
                continue
            if not self.scheduler.grow_for_tokens(st.slot, st.fed + n):
                continue                                   # pages exhausted: slot waits
            if self.prefix_cache is not None:
                # the chunk writes kv positions [fed, fed+n): any shared or
                # trie-registered page in that range must be detached first.
                # On failure the slot waits, but pairs for blocks already
                # detached stay queued in ``copies`` — their fresh pages need
                # the content before any later write or resume.
                lo = (self.pos_offset + st.fed) // cfg.page_size
                hi = (self.pos_offset + st.fed + n - 1) // cfg.page_size
                n_cow = len(copies)
                writable = self.scheduler.make_writable(st.slot, lo, hi, copies)
                if tr and len(copies) > n_cow:
                    tr.event(st.request.req_id, "cow",
                             n_pages=len(copies) - n_cow)
                if not writable:
                    continue                               # no page for the copy: wait
            grants.append((st, n))
        grants = [(st, n) for st, n in grants if st.slot in self.scheduler.running]
        if copies:
            self._apply_copies(copies)                     # before the chunk writes
        if grants:
            t_pack0 = now()
            self._last_prefill_rows = len(grants)
            B, C = self.chunk_rows, self.chunk
            tokens = np.zeros((B, C), np.int32)
            starts = np.zeros((B,), np.int32)
            nvalid = np.zeros((B,), np.int32)
            slots = np.zeros((B,), np.int32)
            first = np.zeros((B,), bool)
            pt = np.zeros((B, cfg.max_pages_per_seq), np.int32)
            used = set()
            for i, (st, n) in enumerate(grants):
                tokens[i, :n] = st.all_tokens[st.fed:st.fed + n]
                starts[i] = st.fed
                nvalid[i] = n
                slots[i] = st.slot
                first[i] = st.fed == 0
                row = self.allocator.page_table_row(st.slot)
                self.page_table[st.slot] = row
                pt[i] = row
                used.add(st.slot)
            # padding rows need distinct (unused) slots: their masked cache
            # writes must never collide with a live row's slot
            spare = [s for s in range(cfg.max_slots) if s not in used]
            for i in range(len(grants), B):
                slots[i] = spare.pop()
            # encoder frames / vision patches only matter on a row's FIRST
            # chunk (cross-KV is persisted per slot; the patch prefix KV is
            # paged) — packs without first chunks skip the prefix compute.
            frames, patches = (self._row_extras(grants, B) if first.any()
                               else (None, None))
            nxt, self.cache = self._step_jit(
                self.params, self.cache, jnp.asarray(tokens), jnp.asarray(starts),
                jnp.asarray(nvalid), jnp.asarray(slots), jnp.asarray(first),
                jnp.asarray(pt), self._next_key(), frames, patches)
            nxt = np.asarray(nxt)
            t_emit = now()
            for i, (st, n) in enumerate(grants):
                st.fed += n
                iter_tokens += n
                self.prefill_tokens += n
                if tr:
                    tr.add(st.request.req_id, "prefill_chunk", t_pack0, t_emit,
                           n_tokens=n, fed=st.fed, rows=len(grants))
                self._register_prefix(st)
                if st.prefilling:
                    continue                               # more chunks to go
                if st.request.generated:                   # resumed mid-decode
                    st.last_token = st.all_tokens[-1]
                    continue
                tok = int(nxt[i])                          # first generated token
                st.last_token = tok
                st.all_tokens.append(tok)
                st.request.generated.append(tok)
                fin = self._check_finished(st, tok)
                events.append(TokenEvent(st.request, tok, t_emit, fin))
                if fin:
                    self._finish(st)

        # ---- decode sweep: the plan's decode-ready set plus slots whose feed
        # completed this iteration (same-step decode, budgeted as grant n+1)
        def _live(st):
            return self.scheduler.running.get(st.slot) is st
        decode_sts = [st for st in plan.decode if _live(st) and st.last_token >= 0]
        decode_sts += [st for st, _ in grants
                       if _live(st) and not st.prefilling and st.last_token >= 0]
        # prompt-lookup drafting for slots the plan granted draft tokens:
        # match the slot's recent suffix against its own prompt+output
        # history; cap so the draft tail never runs past max_seq.
        drafts: Dict[int, List[int]] = {}
        if self.spec_on and not self.degraded:   # brown-out disables drafting
            for st in decode_sts:
                g = min(plan.draft.get(st.slot, 0),
                        cfg.max_seq - 1 - self.pos_offset - st.fed)
                if g > 0:
                    d = self.draft_source.propose(st.all_tokens, g)
                    if d:
                        drafts[st.slot] = d
        dec_copies: List[Tuple[int, int]] = []
        for st in list(decode_sts):
            if st.slot not in self.scheduler.running:      # preempted by an earlier grow
                decode_sts.remove(st)
                continue
            k_i = len(drafts.get(st.slot, ()))
            grown = self.scheduler.grow_for_tokens(st.slot, st.fed + 1 + k_i)
            if not grown and k_i:
                drafts.pop(st.slot, None)                  # retry draft-free
                k_i = 0
                grown = self.scheduler.grow_for_decode(st.slot)
            if not grown:
                decode_sts.remove(st)                      # paused/unschedulable
                continue
            if self.prefix_cache is not None:
                lo = (self.pos_offset + st.fed) // cfg.page_size
                hi = (self.pos_offset + st.fed + k_i) // cfg.page_size
                n_cow = len(dec_copies)
                writable = self.scheduler.make_writable(st.slot, lo, hi,
                                                        dec_copies)
                if tr and len(dec_copies) > n_cow:
                    tr.event(st.request.req_id, "cow",
                             n_pages=len(dec_copies) - n_cow)
                if not writable:
                    decode_sts.remove(st)
                    continue
            self.page_table[st.slot] = self.allocator.page_table_row(st.slot)
        decode_sts = [st for st in decode_sts if st.slot in self.scheduler.running]
        live = {st.slot for st in decode_sts}
        drafts = {s: d for s, d in drafts.items() if s in live}
        if dec_copies:
            self._apply_copies(dec_copies)                 # before the decode writes
        if not decode_sts:
            self.iter_token_counts.append(iter_tokens)
            return events

        M = cfg.max_slots
        # inactive slots must point at the reserved null page 0: a stale row
        # would alias pages freed and reallocated to another sequence.
        for s in range(M):
            if s not in self.scheduler.running:
                self.page_table[s] = 0
        self._last_decode_rows = len(decode_sts)
        if drafts:
            iter_tokens = self._spec_sweep(decode_sts, drafts, events, iter_tokens)
            self.iter_token_counts.append(iter_tokens)
            return events
        t_dec0 = now()
        tokens = np.zeros((M, 1), np.int32)
        starts = np.zeros((M,), np.int32)
        nvalid = np.zeros((M,), np.int32)
        for st in decode_sts:
            tokens[st.slot, 0] = st.last_token
            starts[st.slot] = st.fed
            nvalid[st.slot] = 1
        nxt, self.cache = self._step_jit(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(starts),
            jnp.asarray(nvalid), jnp.asarray(np.arange(M, dtype=np.int32)),
            jnp.asarray(np.zeros((M,), bool)), jnp.asarray(self.page_table),
            self._next_key(), None, None)
        nxt = np.asarray(nxt)
        t_emit = now()
        self.decode_tokens += len(decode_sts)
        iter_tokens += len(decode_sts)

        for st in decode_sts:
            st.fed += 1
            tok = int(nxt[st.slot])
            st.last_token = tok
            st.all_tokens.append(tok)
            st.request.generated.append(tok)
            if tr:
                # consecutive decode iterations coalesce into one span per
                # decode run (broken by preemption/spec/prefill spans)
                tr.add(st.request.req_id, "decode", t_dec0, t_emit,
                       merge=True, n_iters=1, tokens=1)
            fin = self._check_finished(st, tok)
            events.append(TokenEvent(st.request, tok, t_emit, fin))
            if fin:
                self._finish(st)
        self.iter_token_counts.append(iter_tokens)
        return events

    def _spec_sweep(self, decode_sts: List[SlotState], drafts: Dict[int, List[int]],
                    events: List[TokenEvent], iter_tokens: int) -> int:
        """One speculative decode iteration over all decode-ready slots
        (draft-free slots ride along as plain chunks of 1). Feeds
        [last_token, d_1 .. d_k] per row, commits the accepted prefix plus
        the bonus/corrected token, and rolls rejected KV back by truncating
        the slot's page tail (pages are append-only; positions at or past
        ``fed`` are never read and are overwritten by the next write)."""
        cfg = self.cfg
        tr = self.tracer
        t_sw0 = now()
        M = cfg.max_slots
        kcap = max(len(d) for d in drafts.values())
        C = next(w for w in self._spec_widths if w >= 1 + kcap)
        tokens = np.zeros((M, C), np.int32)
        starts = np.zeros((M,), np.int32)
        nvalid = np.zeros((M,), np.int32)
        for st in decode_sts:
            d = drafts.get(st.slot, [])
            tokens[st.slot, 0] = st.last_token
            if d:
                tokens[st.slot, 1:1 + len(d)] = d
            starts[st.slot] = st.fed
            nvalid[st.slot] = 1 + len(d)
        n_acc, out, self.cache = self._spec_jit_for(C)(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(starts),
            jnp.asarray(nvalid), jnp.asarray(np.arange(M, dtype=np.int32)),
            jnp.asarray(np.zeros((M,), bool)), jnp.asarray(self.page_table),
            self._next_key())
        n_acc, out = np.asarray(n_acc), np.asarray(out)
        t_emit = now()
        self.spec_steps += 1
        for st in decode_sts:
            d = drafts.get(st.slot, [])
            k_i = len(d)
            na = int(n_acc[st.slot])
            committed = d[:na] + [int(out[st.slot])]
            iter_tokens += 1 + k_i         # all fed tokens count, rejected too
            self.drafted_tokens += k_i
            self.accepted_tokens += na
            if k_i:
                # adapt K additively: +1 on full acceptance, -1 only when
                # the whole draft was rejected, hold on partial acceptance.
                # Partial acceptance still amortizes the sweep (the verify
                # chunk is one batched call), so only a slot that keeps
                # drafting garbage shrinks toward k=1 — which also narrows
                # the sweep width via the compiled-width ladder.
                if na == k_i:
                    st.spec_k = min(self.spec_kmax, st.spec_k + 1)
                elif na == 0:
                    st.spec_k = max(1, st.spec_k - 1)
            fin = False
            n_committed = 0
            for tok in committed:
                st.fed += 1                # commits the KV of the PREVIOUS token
                st.last_token = tok
                st.all_tokens.append(tok)
                st.request.generated.append(tok)
                self.decode_tokens += 1
                n_committed += 1
                fin = self._check_finished(st, tok)
                events.append(TokenEvent(st.request, tok, t_emit, fin))
                if fin:
                    self._finish(st)       # frees every page, rollback included
                    break
            if tr:
                if k_i:
                    tr.add(st.request.req_id, "spec_verify", t_sw0, t_emit,
                           merge=True, n_iters=1, drafted=k_i, accepted=na,
                           tokens=n_committed)
                else:                      # draft-free row riding the sweep
                    tr.add(st.request.req_id, "decode", t_sw0, t_emit,
                           merge=True, n_iters=1, tokens=n_committed)
            if not fin and na < k_i:
                # rollback the rejected tail: keep pages through the next
                # decode write (position fed), drop pages grown only for
                # rejected drafts. Never touches registered prompt blocks —
                # they precede fed by construction.
                self.scheduler.shrink_to_tokens(st.slot,
                                                self.pos_offset + st.fed + 1)
        return iter_tokens

    def _check_finished(self, st: SlotState, tok: int) -> bool:
        r = st.request
        if len(r.generated) >= r.max_new_tokens:
            return True
        if self.cfg.eos_id >= 0 and tok == self.cfg.eos_id:
            return True
        if st.fed + 1 + self.pos_offset >= self.cfg.max_seq:
            return True                   # kv budget incl. any vision prefix
        return False

    def _drop_extras(self, req_id: str) -> None:
        self.extras.pop((req_id, "frames"), None)
        self.extras.pop((req_id, "patches"), None)

    def _finish(self, st: SlotState) -> None:
        st.request.finished = True
        st.request.t3 = now()
        self.scheduler.finish(st.slot)
        self._drop_extras(st.request.req_id)

    def stats(self) -> Dict[str, float]:
        """Cumulative engine counters (prefix cache, COW, eviction) for the
        observability sink and benchmark extras; sampled at TokenEvent
        granularity by replica/gateway consumers."""
        pc = self.prefix_cache
        return {
            "steps": float(self.steps),
            "prefill_tokens": float(self.prefill_tokens),
            "decode_tokens": float(self.decode_tokens),
            "prefix_cached_tokens": float(self.prefix_cached_tokens),
            "prefix_hit_pages": float(pc.hit_pages if pc else 0),
            "prefix_miss_pages": float(pc.miss_pages if pc else 0),
            "prefix_hit_rate": pc.hit_rate() if pc else 0.0,
            "prefix_nodes": float(len(pc) if pc else 0),
            "cow_copies": float(self.allocator.cow_copies),
            "evicted_pages": float(self.allocator.evicted_pages),
            "retired_pages": float(self.allocator.retired_pages),
            "preemptions": float(self.scheduler.n_preemptions),
            "deadline_exceeded": float(self.deadline_exceeded),
            "kv_utilization": self.allocator.utilization(),
            "spec_steps": float(self.spec_steps),
            "drafted_tokens": float(self.drafted_tokens),
            "accepted_tokens": float(self.accepted_tokens),
            "spec_acceptance_rate": (self.accepted_tokens / self.drafted_tokens
                                     if self.drafted_tokens else 0.0),
        }

    def cancel(self, req_id: str) -> bool:
        """Drop a request (hedging loser / client disconnect). Frees its slot."""
        if self.tracer:
            self.tracer.discard(req_id)
        for i, r in enumerate(self.scheduler.waiting):
            if r.req_id == req_id:
                del self.scheduler.waiting[i]
                self._drop_extras(req_id)
                return True
        for slot, st in list(self.scheduler.running.items()):
            if st.request.req_id == req_id:
                self.scheduler.finish(slot)
                self.page_table[slot] = 0
                self._drop_extras(req_id)
                return True
        return False

    # ------------------------------------------------------------- sync api
    def generate(self, requests: List[Request], max_steps: int = 100_000) -> List[Request]:
        """Blocking helper for tests/benchmarks without the gateway stack."""
        for r in requests:
            r.t0 = r.t0 or now()
            r.t1 = r.t1 or now()
            self.submit(r)
        steps = 0
        while self.has_work() and steps < max_steps:
            for ev in self.step():
                if ev.request.t4 == 0.0:
                    ev.request.t4 = ev.t_emit
                    ev.request.t5 = now()
                if ev.finished:
                    ev.request.t6 = now()
            steps += 1
        return requests
