"""Iteration-level (continuous) batching scheduler with the paper's
max-utilization policy and Sarathi-style token-budget iterations.

Policies:
  max_utilization  admit whenever a slot is free and the first prefill chunk
                   fits in free pages — maximize tokens-in-flight per
                   iteration; if pages run out mid-decode or mid-prefill,
                   PAUSE (preempt) the most recently admitted request,
                   freeing its pages; it re-enters the head of the waiting
                   queue and is re-prefilled later (the paper's "pausing
                   requests if KV cache size limit is reached").
  conservative     admit only if prompt + max_new_tokens worth of pages is
                   free — no preemption can ever be needed.
  static           classic static batching (the HF-endpoint baseline, Fig 2):
                   admit a batch only when the engine is idle, never refill
                   slots until every sequence in the batch finishes.

Token-budget iterations (``plan_iteration``, DESIGN.md §2): every engine
step packs all pending decode tokens plus prefill *chunks* up to a fixed
per-iteration token budget. Long prompts prefill over several iterations
(tracked by ``SlotState.fed`` vs ``SlotState.feed_len``), so an admitted
prompt never stalls running decodes for its full length — the
chunked-prefill fix for TTFT/TPOT interference.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.kv_cache import OutOfPages, PagedAllocator, PrefixCache
from repro.core.metrics import Request
from repro.core.observability import Tracer


@dataclass
class SlotState:
    slot: int
    request: Request
    all_tokens: List[int]          # prompt + generated
    fed: int = 0                   # tokens whose KV is in the cache
    feed_len: int = 0              # tokens to feed before decoding can start
    last_token: int = -1           # sampled but not yet fed
    admitted_at: float = 0.0
    order: int = 0                 # admission sequence number (preemption victim choice)
    cached_tokens: int = 0         # prefix-cache hit: tokens whose prefill was skipped
    registered_blocks: int = 0     # prompt pages already inserted into the prefix trie
    spec_k: int = 0                # draft-token allowance (engine-adapted; 0 = no drafting)

    @property
    def prefilling(self) -> bool:
        return self.fed < self.feed_len


@dataclass
class Decisions:
    admit: List[SlotState] = field(default_factory=list)


@dataclass
class IterationPlan:
    """One token-budget iteration: freshly admitted slots, prefill-chunk
    grants (slot, n_tokens), the decode-ready set, and per-slot draft-token
    grants (speculative decoding; slot -> extra tokens the decode row may
    feed this iteration). Token accounting: sum of grant costs + len(decode)
    + sum(draft grants) <= budget, where a prefill grant that completes a
    slot's feed costs n+1 (the slot decodes in the same iteration)."""
    admit: List[SlotState] = field(default_factory=list)
    prefill: List[Tuple[SlotState, int]] = field(default_factory=list)
    decode: List[SlotState] = field(default_factory=list)
    draft: Dict[int, int] = field(default_factory=dict)


class ContinuousBatchScheduler:
    def __init__(self, max_slots: int, allocator: PagedAllocator,
                 policy: str = "max_utilization", max_seq: int = 4096,
                 kv_extra: int = 0, prefix_cache: Optional[PrefixCache] = None,
                 tracer: Optional[Tracer] = None):
        assert policy in ("max_utilization", "conservative", "static")
        # prefix sharing assumes token position == kv position; a kv prefix
        # (VLM patches) shifts every page, so the two are mutually exclusive
        assert prefix_cache is None or kv_extra == 0
        self.max_slots = max_slots
        self.allocator = allocator
        self.policy = policy
        self.max_seq = max_seq
        self.kv_extra = kv_extra       # per-seq kv prefix (e.g. VLM patches)
        self.prefix_cache = prefix_cache
        self.tracer = tracer
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, SlotState] = {}
        self._order = 0
        self.n_preemptions = 0

    # ------------------------------------------------------------------
    def add(self, request: Request, *, front: bool = False) -> None:
        if self.tracer:
            # one queue span per wait (re-opened on preempt re-queue);
            # closed by the engine at admission
            self.tracer.begin(request.req_id, "queue", requeued=front)
        if front:
            self.waiting.appendleft(request)
        else:
            self.waiting.append(request)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    # ------------------------------------------------------------------
    def _pages_for(self, req: Request, restored: int, chunk: int = 0) -> int:
        prompt_len = len(req.prompt_tokens) + restored
        if self.policy == "conservative":
            need = prompt_len + req.max_new_tokens
        elif chunk > 0:
            # chunked admission: only the first chunk (or whole short prompt
            # + one decode token) must fit now; later chunks grow page by
            # page with preemption backpressure.
            need = min(prompt_len + 1, chunk)
        else:
            need = prompt_len + 1          # max utilization: prompt + headroom
        return self.allocator.pages_needed(self.kv_extra + need)

    def schedule(self, chunk: int = 0) -> Decisions:
        d = Decisions()
        if self.policy == "static" and self.running:
            return d                        # static: wait for the whole batch
        free = self.free_slots()
        pending_pages = 0                  # pages this round's admissions will take
        while self.waiting and free:
            req = self.waiting[0]
            restored = max(len(req.generated) - 1, 0)
            all_tokens = list(map(int, req.prompt_tokens)) + list(req.generated)
            feed_len = len(all_tokens) - (1 if req.generated else 0)
            # prefix-cache probe: leading full pages whose KV already exists
            # cost nothing beyond a page-table entry; at least one token is
            # always left to feed so the chunk call yields last-token logits.
            shared: List[int] = []
            n_cached = 0
            if self.prefix_cache is not None and feed_len > 0:
                shared = self.prefix_cache.lookup(
                    all_tokens[:feed_len],
                    record=False)[: self.allocator.max_pages_per_seq]
                if shared:
                    n_cached = min(len(shared) * self.allocator.page_size,
                                   feed_len - 1)
            revive = 0
            if shared:
                # only the uncached remainder needs fresh pages now
                if self.policy == "conservative":
                    tokens_now = feed_len + req.max_new_tokens
                elif chunk > 0:
                    tokens_now = min(feed_len + 1, n_cached + chunk)
                else:
                    tokens_now = feed_len + 1
                need = max(self.allocator.pages_needed(tokens_now) - len(shared), 0)
                # reviving a retired shared page consumes LRU capacity that
                # free_pages still counts as allocatable — bill it as demand,
                # or admission over-commits and leans on OutOfPages/preemption
                revive = sum(1 for p in shared if self.allocator.retired(p))
            else:
                need = self._pages_for(req, restored, chunk)
            if need + revive + pending_pages > self.allocator.free_pages:
                break
            # revived pages leave free_pages at the share() below; only the
            # fresh-page demand carries forward to later candidates
            pending_pages += need
            if self.prefix_cache is not None and feed_len > 0:
                self.prefix_cache.record_probe(feed_len, len(shared))
            self.waiting.popleft()
            slot = free.pop(0)
            st = SlotState(slot=slot, request=req, all_tokens=all_tokens,
                           feed_len=feed_len, fed=n_cached,
                           cached_tokens=n_cached,
                           registered_blocks=len(shared), order=self._order)
            if shared:
                self.allocator.share(slot, shared)
            self._order += 1
            self.running[slot] = st
            d.admit.append(st)
        return d

    # ------------------------------------------------------------------
    def plan_iteration(self, budget: int, chunk: int,
                       max_chunk_rows: int) -> IterationPlan:
        """Pack one engine iteration: every decode-ready slot contributes its
        pending token; the remaining budget is granted to prefilling slots as
        chunks of up to ``chunk`` tokens (at most ``max_chunk_rows`` rows,
        the fixed shape of the engine's chunk call), oldest first."""
        plan = IterationPlan()
        plan.admit = self.schedule(chunk=chunk).admit
        plan.decode = [st for st in self.running.values()
                       if not st.prefilling and st.last_token >= 0]
        spent = len(plan.decode)
        # speculative draft grants: after every decode slot's guaranteed
        # token, leftover budget buys draft tokens (oldest slot first) up to
        # each slot's adaptive allowance. Draft tokens compete with prefill
        # chunks for the same budget — a draft the verify step rejects was
        # still fed through the model.
        for st in sorted(plan.decode, key=lambda s: s.order):
            if st.spec_k <= 0:
                continue
            g = min(st.spec_k, budget - spent)
            if g <= 0:
                break
            plan.draft[st.slot] = g
            spent += g
        prefilling = sorted((st for st in self.running.values() if st.prefilling),
                            key=lambda st: st.order)
        for st in prefilling:
            if len(plan.prefill) >= max_chunk_rows:
                break
            left = budget - spent
            if left <= 0:
                break
            n = min(chunk, st.feed_len - st.fed, left)
            completes = n == st.feed_len - st.fed
            if completes and n + 1 > left:
                n -= 1                     # leave room for the same-step decode
                completes = False
            if n <= 0:
                break
            plan.prefill.append((st, n))
            spent += n + (1 if completes else 0)
        return plan

    # ------------------------------------------------------------------
    def expire_deadlines(self, t: float) -> List[Tuple[Optional[int], Request]]:
        """Deadline-exceeded cancellation (DESIGN.md §5): drop every waiting
        or running request whose ``deadline_at`` has passed. Running slots go
        through ``finish`` so their pages are freed with full refcount
        semantics (shared prefix pages decref, COW-detached pages return to
        the free list). Returns ``(slot, request)`` pairs — ``slot`` is None
        for requests still in the waiting queue — so the engine can emit the
        terminal events and clear its page-table rows."""
        out: List[Tuple[Optional[int], Request]] = []
        for i in reversed(range(len(self.waiting))):
            r = self.waiting[i]
            if r.deadline_at and t > r.deadline_at:
                del self.waiting[i]
                if self.tracer:
                    self.tracer.end(r.req_id, "queue", expired=True)
                out.append((None, r))
        for slot, st in list(self.running.items()):
            r = st.request
            if r.deadline_at and t > r.deadline_at:
                self.finish(slot)
                out.append((slot, r))
        return out

    # ------------------------------------------------------------------
    def preempt_one(self, protect: Optional[int] = None) -> Optional[int]:
        """Pause the most recently admitted running request (vLLM-style
        latest-first victim), freeing its pages. Returns the freed slot."""
        victims = [st for st in self.running.values() if st.slot != protect]
        if not victims:
            return None
        victim = max(victims, key=lambda st: st.order)
        victim.request.preemptions += 1
        self.n_preemptions += 1
        if self.tracer:
            self.tracer.event(victim.request.req_id, "preempt",
                              fed=victim.fed, order=victim.order)
        self.allocator.free(victim.slot)
        del self.running[victim.slot]
        self.add(victim.request, front=True)
        return victim.slot

    def finish(self, slot: int) -> None:
        self.allocator.free(slot)
        del self.running[slot]

    def grow_for_tokens(self, slot: int, n_tokens: int) -> bool:
        """Ensure slot owns pages covering ``n_tokens`` kv entries (plus the
        kv_extra prefix); preempt others if the policy allows. Returns False
        if the slot itself must pause."""
        st = self.running[slot]
        while True:
            try:
                self.allocator.allocate(slot, self.kv_extra + n_tokens)
                return True
            except OutOfPages:
                if self.policy != "max_utilization":
                    return False
                if self.preempt_one(protect=slot) is None:
                    return False

    def grow_for_decode(self, slot: int) -> bool:
        """Ensure slot has a page for one more token; preempt others if the
        policy allows. Returns False if the slot itself must pause."""
        return self.grow_for_tokens(slot, self.running[slot].fed + 1)

    def shrink_to_tokens(self, slot: int, n_tokens: int) -> int:
        """Rollback partner of ``grow_for_tokens``: drop pages past those
        covering ``n_tokens`` kv entries (plus the kv_extra prefix). Used
        after speculative verify rejects draft tokens, so pages grown for a
        rejected tail never sit idle under page pressure."""
        keep = self.allocator.pages_needed(self.kv_extra + n_tokens)
        return self.allocator.truncate(slot, keep)

    def make_writable(self, slot: int, first_block: int, last_block: int,
                      copies: List[Tuple[int, int]]) -> bool:
        """Copy-on-write entry point: detach any shared/cached pages in the
        slot's logical range [first_block, last_block] onto fresh pages
        (preempting under page pressure, like growth). The (src, dst) device
        page copies are appended to ``copies`` — including pairs from blocks
        detached before an ``OutOfPages``, which the caller MUST still apply
        even on failure (those blocks already point at fresh pages holding
        garbage). Returns False if the slot itself must pause: the range is
        not fully exclusive and must not be written."""
        while True:
            try:
                self.allocator.ensure_exclusive(slot, first_block, last_block,
                                                copies=copies)
                return True
            except OutOfPages:
                if self.policy != "max_utilization":
                    return False
                if self.preempt_one(protect=slot) is None:
                    return False
