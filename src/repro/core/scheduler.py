"""Iteration-level (continuous) batching scheduler with the paper's
max-utilization policy.

Policies:
  max_utilization  admit whenever a slot is free and the *prompt* fits in
                   free pages — maximize tokens-in-flight per iteration; if
                   pages run out mid-decode, PAUSE (preempt) the most recently
                   admitted request, freeing its pages; it re-enters the head
                   of the waiting queue and is re-prefilled later (the paper's
                   "pausing requests if KV cache size limit is reached").
  conservative     admit only if prompt + max_new_tokens worth of pages is
                   free — no preemption can ever be needed.
  static           classic static batching (the HF-endpoint baseline, Fig 2):
                   admit a batch only when the engine is idle, never refill
                   slots until every sequence in the batch finishes.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.kv_cache import OutOfPages, PagedAllocator
from repro.core.metrics import Request


@dataclass
class SlotState:
    slot: int
    request: Request
    all_tokens: List[int]          # prompt + generated
    fed: int = 0                   # tokens whose KV is in the cache
    last_token: int = -1           # sampled but not yet fed
    admitted_at: float = 0.0
    order: int = 0                 # admission sequence number (preemption victim choice)


@dataclass
class Decisions:
    admit: List[SlotState] = field(default_factory=list)


class ContinuousBatchScheduler:
    def __init__(self, max_slots: int, allocator: PagedAllocator,
                 policy: str = "max_utilization", max_seq: int = 4096):
        assert policy in ("max_utilization", "conservative", "static")
        self.max_slots = max_slots
        self.allocator = allocator
        self.policy = policy
        self.max_seq = max_seq
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, SlotState] = {}
        self._order = 0
        self.n_preemptions = 0

    # ------------------------------------------------------------------
    def add(self, request: Request, *, front: bool = False) -> None:
        if front:
            self.waiting.appendleft(request)
        else:
            self.waiting.append(request)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    # ------------------------------------------------------------------
    def _pages_for(self, req: Request, restored: int) -> int:
        prompt_len = len(req.prompt_tokens) + restored
        if self.policy == "conservative":
            need = prompt_len + req.max_new_tokens
        else:
            need = prompt_len + 1          # max utilization: prompt + headroom
        return self.allocator.pages_needed(need)

    def schedule(self) -> Decisions:
        d = Decisions()
        if self.policy == "static" and self.running:
            return d                        # static: wait for the whole batch
        free = self.free_slots()
        pending_pages = 0                  # pages this round's admissions will take
        while self.waiting and free:
            req = self.waiting[0]
            restored = max(len(req.generated) - 1, 0)
            need = self._pages_for(req, restored)
            if need + pending_pages > self.allocator.free_pages:
                break
            pending_pages += need
            self.waiting.popleft()
            slot = free.pop(0)
            all_tokens = list(map(int, req.prompt_tokens)) + list(req.generated)
            st = SlotState(slot=slot, request=req, all_tokens=all_tokens,
                           order=self._order)
            self._order += 1
            self.running[slot] = st
            d.admit.append(st)
        return d

    # ------------------------------------------------------------------
    def preempt_one(self, protect: Optional[int] = None) -> Optional[int]:
        """Pause the most recently admitted running request (vLLM-style
        latest-first victim), freeing its pages. Returns the freed slot."""
        victims = [st for st in self.running.values() if st.slot != protect]
        if not victims:
            return None
        victim = max(victims, key=lambda st: st.order)
        victim.request.preemptions += 1
        self.n_preemptions += 1
        self.allocator.free(victim.slot)
        del self.running[victim.slot]
        self.add(victim.request, front=True)
        return victim.slot

    def finish(self, slot: int) -> None:
        self.allocator.free(slot)
        del self.running[slot]

    def grow_for_decode(self, slot: int) -> bool:
        """Ensure slot has a page for one more token; preempt others if the
        policy allows. Returns False if the slot itself must pause."""
        st = self.running[slot]
        while True:
            try:
                self.allocator.allocate(slot, st.fed + 1)
                return True
            except OutOfPages:
                if self.policy != "max_utilization":
                    return False
                if self.preempt_one(protect=slot) is None:
                    return False
