"""Async client workload driver — simulates users submitting OpenAI-API-style
requests "in a concurrent and continuous manner" (paper §5): a fixed
concurrency window of in-flight requests, 20 x concurrency total requests,
streaming consumption with client-side t0/t5/t6 timestamps.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.gateway import Gateway
from repro.core.metrics import Request, now
from repro.core.serde import CODECS


@dataclass
class ClientResult:
    requests: List[Request]
    t_start: float
    t_end: float


async def run_workload(
    gateway: Gateway,
    prompts: List[np.ndarray],
    *,
    concurrency: int,
    max_new_tokens: int = 64,
    timeout_s: float = 60.0,
    auth_token: str = "",
    arrivals: Optional[Sequence[float]] = None,
    extra_params: Optional[Sequence[Optional[dict]]] = None,
) -> ClientResult:
    """Closed loop by default (a ``concurrency``-wide window of in-flight
    requests). With ``arrivals`` — offsets in seconds from the start, e.g.
    from ``sample_arrivals`` — runs open loop: request *i* is submitted at
    ``t_start + arrivals[i]`` regardless of how many are in flight, the
    arrival pattern production traffic actually has (``concurrency`` is
    ignored). ``extra_params[i]`` (e.g. ``{"deadline_s": 0.05, "greedy":
    True}``) merges into request *i*'s wire params."""
    codec = CODECS[gateway.cfg.codec]
    sem = asyncio.Semaphore(concurrency)
    requests: List[Request] = []
    t_start = now()

    async def one(i: int, prompt: np.ndarray) -> Request:
        if arrivals is not None:
            await asyncio.sleep(max(0.0, t_start + arrivals[i] - now()))
            return await _one_body(i, prompt)
        async with sem:
            return await _one_body(i, prompt)

    async def _one_body(i: int, prompt: np.ndarray) -> Request:
        req_id = f"req-{i}"
        shadow = Request(req_id=req_id, prompt_tokens=prompt,
                         max_new_tokens=max_new_tokens)
        requests.append(shadow)
        shadow.t0 = now()
        params = {"max_new_tokens": max_new_tokens}
        if extra_params is not None and extra_params[i]:
            params.update(extra_params[i])
        raw = codec.encode_request(req_id, prompt.tolist(), params)
        q: "asyncio.Queue[bytes]" = asyncio.Queue()
        await gateway.handle(raw, q, auth_token=auth_token)
        n = 0
        while True:
            try:
                data = await asyncio.wait_for(q.get(), timeout=timeout_s)
            except asyncio.TimeoutError:
                shadow.error = "timeout"
                break
            if data == b"":
                shadow.error = "rejected"
                break
            _, token, idx, fin = codec.decode_token(data)
            t = now()
            if shadow.t5 == 0.0:
                shadow.t5 = t
            if token >= 0:             # < 0: terminal no-token sentinel
                shadow.generated.append(token)
                shadow.token_times.append(t)
                n += 1
            if fin:
                shadow.t6 = t
                shadow.finished = True
                break
        return shadow

    await asyncio.gather(*(one(i, p) for i, p in enumerate(prompts)))
    t_end = now()
    return ClientResult(requests=requests, t_start=t_start, t_end=t_end)


def merge_engine_timestamps(client_reqs: List[Request], gateway: Gateway) -> None:
    """Join the client-side shadows (t0/t5/t6, received tokens) with the
    gateway-side records (t1..t4, preemptions, replica id) by req_id — the
    same log-join the paper's end-to-end measurement performs."""
    for r in client_reqs:
        g = gateway.requests.get(r.req_id)
        if g is None:
            continue
        r.t1, r.t2, r.t3, r.t4 = g.t1, g.t2, g.t3, g.t4
        r.preemptions = g.preemptions
        r.replica_id = g.replica_id
        r.hedged = g.hedged
        r.error = r.error or g.error          # shed / deadline / no-replica
        r.retries = g.retries
        r.deadline_s = g.deadline_s
