"""Request lifecycle + the paper's §5.1 metrics.

Timestamps (paper Figure 4):
  t0 user submits          t1 router receives        t2 engine starts inference
  t3 engine finishes       t4 gateway received first engine output
  t5 user receives first token                       t6 user receives full output

Metrics:
  average latency   = t5 - t0   (paper's formula; we also report t6 - t0)
  gateway latency   = (t2 - t0) + (t5 - t3)
  engine latency    = t3 - t2
  throughput        = N_tokens / (T1 - T0)
  TTFT              = t4 - t0   (paper formula; t5-t0 from the user side)
  TBT               = (t6 - t5) / (N_g - 1)   [seconds/token, like every
                       duration here; the paper's printed formula is its
                       reciprocal — see DESIGN.md §9]
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


def now() -> float:
    return time.perf_counter()


@dataclass
class Request:
    req_id: str
    prompt_tokens: np.ndarray                 # (S,) int32
    max_new_tokens: int = 64
    temperature: float = 0.5
    top_p: float = 0.7
    greedy: bool = False
    auth_token: str = ""
    user_id: str = "anon"
    # lifecycle timestamps
    t0: float = 0.0
    t1: float = 0.0
    t2: float = 0.0
    t3: float = 0.0
    t4: float = 0.0
    t5: float = 0.0
    t6: float = 0.0
    # outputs
    generated: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)   # client-side receive times
    finished: bool = False
    error: Optional[str] = None
    preemptions: int = 0
    replica_id: Optional[str] = None
    hedged: bool = False
    # request-lifecycle hardening (DESIGN.md §5): a deadline budget in
    # seconds (propagated gateway -> router -> scheduler) and its absolute
    # cutoff on the monotonic clock (t1 + deadline_s); 0.0 = no deadline.
    deadline_s: Optional[float] = None
    deadline_at: float = 0.0
    retries: int = 0                          # transient-submit retries spent

    @property
    def n_generated(self) -> int:
        return len(self.generated)


@dataclass
class RequestMetrics:
    req_id: str
    avg_latency: float          # t5 - t0 (paper formula)
    full_latency: float         # t6 - t0
    gateway_latency: float      # (t2-t0)+(t5-t3)
    engine_latency: float       # t3 - t2
    ttft: float                 # t4 - t0
    ttft_user: float            # t5 - t0
    tbt: float                  # (t6-t5)/(Ng-1) seconds per token
    n_tokens: int
    preemptions: int
    timed_out: bool


def request_metrics(r: Request, timeout_s: float = 60.0) -> RequestMetrics:
    ng = max(r.n_generated, 1)
    tbt = (r.t6 - r.t5) / (ng - 1) if ng > 1 else 0.0
    return RequestMetrics(
        req_id=r.req_id,
        avg_latency=r.t5 - r.t0,
        full_latency=r.t6 - r.t0,
        gateway_latency=(r.t2 - r.t0) + (r.t5 - r.t3 if r.t5 > r.t3 else 0.0),
        engine_latency=r.t3 - r.t2,
        ttft=r.t4 - r.t0,
        ttft_user=r.t5 - r.t0,
        tbt=tbt,
        n_tokens=r.n_generated,
        preemptions=r.preemptions,
        timed_out=(r.t6 - r.t0) > timeout_s or not r.finished,
    )


@dataclass
class BenchmarkSummary:
    concurrency: int
    n_requests: int
    throughput_tok_s: float
    mean: Dict[str, float]
    p50: Dict[str, float]
    p99: Dict[str, float]
    timeout_frac: float
    extras: Dict[str, Any] = field(default_factory=dict)


def summarize(requests: List[Request], t_start: float, t_end: float,
              concurrency: int, timeout_s: float = 60.0,
              extras: Optional[Dict[str, Any]] = None) -> BenchmarkSummary:
    """``extras`` carries engine-level counters (prefix-cache hit rate, COW
    copies, evictions — see ``InferenceEngine.stats``) alongside the
    request-latency aggregates."""
    ms = [request_metrics(r, timeout_s) for r in requests]
    total_tokens = sum(m.n_tokens for m in ms)
    fields = ["avg_latency", "full_latency", "gateway_latency", "engine_latency",
              "ttft", "ttft_user", "tbt"]

    def agg(fn):
        return {f: fn([getattr(m, f) for m in ms]) if ms else 0.0 for f in fields}

    return BenchmarkSummary(
        concurrency=concurrency,
        n_requests=len(requests),
        throughput_tok_s=total_tokens / max(t_end - t_start, 1e-9),
        mean=agg(lambda v: float(statistics.fmean(v)) if v else 0.0),
        p50=agg(lambda v: float(np.percentile(v, 50)) if v else 0.0),
        p99=agg(lambda v: float(np.percentile(v, 99)) if v else 0.0),
        timeout_frac=sum(m.timed_out for m in ms) / max(len(ms), 1),
        extras=dict(extras or {}),
    )
