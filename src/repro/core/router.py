"""Replica router: load balancing, failover, straggler hedging, and the §6
dynamic-blueprint policy.

Policies:
  round_robin    cycle through healthy replicas
  least_loaded   min(active + queued)
  dynamic        the paper's blueprint: concurrency < threshold -> route to
                 the "high_tp" replica class (few big replicas, best at small
                 batch); >= threshold -> the "high_replica" class (many small
                 replicas, best at high concurrency). Least-loaded inside the
                 chosen class; falls through to the other class if one is
                 empty/unhealthy.

Fault tolerance:
  - failover: when a replica dies, its in-flight requests (with partial
    generations) are resubmitted to healthy replicas and RESUME mid-stream
    (the engine re-prefills prompt+generated).
  - hedging: if a request produces no first token within ``hedge_after_s``,
    a shadow copy is dispatched to another replica; the first stream to
    produce tokens wins and the loser is cancelled.
"""
from __future__ import annotations

import copy
import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


from repro.core.engine import TokenEvent
from repro.core.faults import FaultInjector, TransientSubmitError
from repro.core.metrics import Request, now
from repro.core.observability import MetricsSink, Tracer
from repro.core.replica import OnEvent, Replica


class NoReplicaAvailable(Exception):
    pass


@dataclass
class RouterConfig:
    policy: str = "least_loaded"            # round_robin | least_loaded | dynamic
    dynamic_threshold: int = 64             # paper §6: <64 -> high TP; >=64 -> replicas
    hedge_after_s: Optional[float] = None   # straggler hedging deadline (None = off)
    retry_budget: int = 2                   # transient-submit retries per request
    retry_backoff_s: float = 0.005          # exponential backoff base; kept tiny
                                            # because submit can run on the
                                            # gateway's event-loop thread
    monitor_interval_s: float = 0.05        # health-monitor poll period


@dataclass
class FailoverEvent:
    """One detected replica failure: when, which replica, why (manual |
    crash | stall), how long between the replica's last heartbeat and
    detection, and how many in-flight requests were re-dispatched."""
    t: float
    replica_id: str
    reason: str
    latency_s: float
    n_requests: int = 0
    extra: Dict[str, float] = field(default_factory=dict)


class ReplicaRouter:
    def __init__(self, replicas: List[Replica], cfg: Optional[RouterConfig] = None,
                 sink: Optional[MetricsSink] = None,
                 tracer: Optional[Tracer] = None,
                 injector: Optional[FaultInjector] = None):
        self.replicas = list(replicas)
        self.cfg = cfg or RouterConfig()
        self.sink = sink or MetricsSink()
        self.tracer = tracer
        self.injector = injector             # transient submit-error hook
        self._rr = 0
        self._lock = threading.Lock()
        self._live = 0                       # live concurrency estimate
        self._hedges: Dict[str, dict] = {}
        # per-request delivery state (DESIGN.md §5): terminal guard (no event
        # after the terminal one — retry/failover/hedge never double-deliver),
        # the armed hedge timer (cancelled at terminal: the timer-leak fix),
        # and the shadow to reap when the primary wins.
        self._req_state: Dict[str, dict] = {}
        self._fail_lock = threading.Lock()
        self._failed: set = set()            # replica ids already failed over
        self.failover_events: List[FailoverEvent] = []
        self.manual_failovers = 0
        self.auto_failovers = 0
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()

    # ------------------------------------------------------------- selection
    def _healthy(self) -> List[Replica]:
        return [r for r in self.replicas if r.healthy]

    def add_replica(self, replica: Replica) -> None:
        """Elastic scale-out."""
        with self._lock:
            self.replicas.append(replica)

    def remove_replica(self, replica_id: str) -> None:
        """Elastic scale-in (drain is the caller's concern)."""
        with self._lock:
            self.replicas = [r for r in self.replicas if r.replica_id != replica_id]

    def select(self) -> Replica:
        healthy = self._healthy()
        if not healthy:
            raise NoReplicaAvailable("no healthy replicas")
        policy = self.cfg.policy
        if policy == "round_robin":
            with self._lock:
                r = healthy[self._rr % len(healthy)]
                self._rr += 1
            return r
        if policy == "dynamic":
            want = "high_tp" if self._live < self.cfg.dynamic_threshold else "high_replica"
            klass = [r for r in healthy if r.klass == want]
            pool = klass or healthy
            return min(pool, key=lambda r: r.load)
        return min(healthy, key=lambda r: r.load)

    # ------------------------------------------------------------- delivery
    def _deliver(self, rid: str, on_event: OnEvent, ev: TokenEvent) -> None:
        """Terminal-guarded delivery for ``rid``: drops any event after the
        request's terminal event (idempotency across retry, failover, and
        hedging), cancels the hedge timer and reaps the shadow at terminal,
        and closes out router accounting exactly once."""
        timer = shadow = None
        with self._lock:
            st = self._req_state.get(rid)
            if st is None or st["terminal"]:
                return
            st["got_first"] = True
            if ev.finished:
                st["terminal"] = True
                timer = st.get("timer")
                shadow = st.get("shadow")
                self._req_state.pop(rid, None)
                self._live -= 1
        if ev.finished:
            if timer is not None:
                timer.cancel()               # hedge-timer leak fix: a request
                                             # finishing before hedge_after_s
                                             # must not leave a live Timer
            if shadow is not None:
                backup, shadow_id = shadow
                backup.cancel(shadow_id)
            self.sink.record_request(ev.request)
            if self.tracer:
                # the request's span list is complete once its terminal
                # event fires — export through the JSONL sink and drop
                self.sink.record_trace(ev.request, self.tracer.pop(rid))
        on_event(ev)

    @staticmethod
    def _jitter(rid: str, attempt: int) -> float:
        """Deterministic backoff jitter in [0.5, 1.5): a pure hash of
        (req_id, attempt), so retry timing replays under a fixed schedule."""
        h = hashlib.blake2b(f"{rid}:{attempt}".encode(), digest_size=2).digest()
        return 0.5 + int.from_bytes(h, "little") / 65536.0

    # ------------------------------------------------------------- dispatch
    def submit(self, request: Request, on_event: OnEvent,
               replica: Optional[Replica] = None) -> Replica:
        t_route0 = now()
        rid = request.req_id
        tracer = self.tracer
        with self._lock:
            if rid not in self._req_state:
                self._req_state[rid] = {"terminal": False, "got_first": False,
                                        "timer": None, "shadow": None}
                self._live += 1

        def wrapped(ev: TokenEvent) -> None:
            self._deliver(rid, on_event, ev)

        if replica is None or not replica.healthy:
            replica = self.select()
        # transient submit errors are retried against the budget with
        # exponential backoff + deterministic jitter; exhaustion emits a
        # terminal error event through the guard — a shed, never a hang.
        attempt = 0
        while True:
            try:
                if self.injector is not None:
                    self.injector.on_submit(replica.replica_id, rid, attempt)
                replica.submit(request, wrapped)
                break
            except (TransientSubmitError, RuntimeError, NoReplicaAvailable) as e:
                attempt += 1
                if attempt > self.cfg.retry_budget:
                    request.error = f"submit failed after {attempt} attempts: {e}"
                    request.finished = True
                    self.sink.incr("retry_exhausted")
                    if tracer:
                        tracer.event(rid, "retry_exhausted", attempts=attempt)
                    wrapped(TokenEvent(request, -1, now(), True))
                    return replica
                request.retries += 1
                self.sink.incr("retries")
                if tracer:
                    tracer.event(rid, "retry", attempt=attempt, error=str(e))
                time.sleep(self.cfg.retry_backoff_s * (2 ** (attempt - 1))
                           * self._jitter(rid, attempt))
                try:
                    replica = self.select()
                except NoReplicaAvailable as e2:
                    e = e2               # loop once more; budget decides
        if tracer:
            tracer.add(rid, "route", t_route0, now(),
                       replica=replica.replica_id, policy=self.cfg.policy,
                       attempts=attempt + 1)
        self.sink.incr(f"routed_to.{replica.replica_id}")

        if self.cfg.hedge_after_s is not None:
            timer = threading.Timer(self.cfg.hedge_after_s, self._maybe_hedge,
                                    args=(request, replica, on_event))
            timer.daemon = True
            with self._lock:
                st = self._req_state.get(rid)
                if st is not None and not st["terminal"] and st["timer"] is None:
                    st["timer"] = timer
                else:
                    timer = None         # finished before the timer armed
            if timer is not None:
                timer.start()
        return replica

    # ------------------------------------------------------------- hedging
    def _maybe_hedge(self, request: Request, primary: Replica,
                     on_event: OnEvent) -> None:
        rid = request.req_id
        with self._lock:
            st = self._req_state.get(rid)
            if st is None or st["terminal"] or st["got_first"]:
                return
        if request.finished or not primary.healthy:
            return
        others = [r for r in self._healthy() if r.replica_id != primary.replica_id]
        if not others:
            return
        shadow = copy.deepcopy(request)
        shadow.req_id = rid + "#hedge"
        shadow.hedged = True
        request.hedged = True
        winner_decided = {"v": False}
        self.sink.incr("hedges")
        if self.tracer:
            self.tracer.event(rid, "hedge", primary=primary.replica_id)

        def shadow_events(ev: TokenEvent) -> None:
            if not winner_decided["v"]:
                winner_decided["v"] = True
                primary.cancel(rid)
                self.sink.incr("hedge_wins")
            if ev.request.req_id.endswith("#hedge"):
                # merge shadow progress into the primary request object and
                # deliver through the terminal guard (a dead-heat primary
                # terminal and shadow terminal can never both reach the
                # client)
                request.generated = ev.request.generated
                request.t2, request.t3 = ev.request.t2, ev.request.t3
                request.finished = ev.request.finished
                self._deliver(rid, on_event,
                              TokenEvent(request, ev.token, ev.t_emit, ev.finished))

        backup = min(others, key=lambda r: r.load)
        try:
            backup.submit(shadow, shadow_events)
        except (TransientSubmitError, RuntimeError):
            return                            # hedging is best-effort
        with self._lock:
            st = self._req_state.get(rid)
            if st is None or st["terminal"]:
                # primary finished while we were dispatching: reap the shadow
                backup.cancel(shadow.req_id)
                return
            st["shadow"] = (backup, shadow.req_id)

    # ------------------------------------------------------------- failover
    def handle_failure(self, replica: Replica, reason: str = "manual") -> int:
        """Re-dispatch a dead replica's in-flight requests; returns count.
        Idempotent per replica (monitor sweep and a manual call can race).
        ``reason`` is "manual" | "crash" | "stall" — crash/stall come from
        the automatic detector in :meth:`health_sweep`."""
        with self._fail_lock:
            if replica.replica_id in self._failed:
                return 0
            self._failed.add(replica.replica_id)
        # heartbeat -> detection gap on the replica's own monotonic clock
        latency_s = time.monotonic() - replica.last_step_at
        orphans = replica.kill()
        n = 0
        for req, cb in orphans:
            req.finished = False
            try:
                target = self.select()
            except NoReplicaAvailable:
                # orphan fix: the client must observe a terminal event, not
                # hang until its own timeout
                req.error = "no replica for failover"
                req.finished = True
                self.sink.incr("failover_dropped")
                if self.tracer:
                    self.tracer.event(req.req_id, "failover_dropped",
                                      from_replica=replica.replica_id)
                cb(TokenEvent(req, -1, now(), True))
                continue
            target.submit(req, cb)
            self.sink.incr("failovers")
            if self.tracer:
                self.tracer.event(req.req_id, "failover",
                                  from_replica=replica.replica_id,
                                  to_replica=target.replica_id, reason=reason)
            n += 1
        if reason == "manual":
            self.manual_failovers += 1
        else:
            self.auto_failovers += 1
        self.sink.incr(f"failover_{reason}")
        self.sink.observe("failover_latency_s", latency_s)
        with self._lock:
            self.failover_events.append(FailoverEvent(
                t=now(), replica_id=replica.replica_id, reason=reason,
                latency_s=latency_s, n_requests=n))
        return n

    def health_sweep(self) -> List[str]:
        """Automatic failure detection (DESIGN.md §5): a dead serving thread
        is a crash, an expired step watchdog is a stall; both fail over
        without manual intervention."""
        failed = []
        for r in list(self.replicas):
            if not r.healthy:
                continue
            if getattr(r, "thread_dead", lambda: False)():
                self.handle_failure(r, reason="crash")
                failed.append(r.replica_id)
            elif r.watchdog_expired():
                self.handle_failure(r, reason="stall")
                failed.append(r.replica_id)
        return failed

    def start_monitor(self, interval_s: Optional[float] = None) -> None:
        """Spawn the health-monitor thread: a periodic :meth:`health_sweep`
        turning watchdog expiry / thread death into automatic failover."""
        if self._monitor is not None:
            return
        period = interval_s if interval_s is not None else self.cfg.monitor_interval_s

        def _run() -> None:
            while not self._monitor_stop.wait(period):
                try:
                    self.health_sweep()
                except Exception:            # the monitor must never die
                    self.sink.incr("monitor_errors")

        self._monitor = threading.Thread(target=_run, name="router-monitor",
                                         daemon=True)
        self._monitor.start()

    def stop_monitor(self) -> None:
        if self._monitor is None:
            return
        self._monitor_stop.set()
        self._monitor.join(timeout=2)
        self._monitor = None
        self._monitor_stop.clear()

    # ------------------------------------------------------------- degradation
    def set_degraded(self, on: bool) -> None:
        """Broadcast the gateway's brown-out state to every replica (disables
        speculative drafting while overloaded)."""
        for r in list(self.replicas):
            if hasattr(r, "set_degraded"):
                r.set_degraded(on)
