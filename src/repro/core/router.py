"""Replica router: load balancing, failover, straggler hedging, and the §6
dynamic-blueprint policy.

Policies:
  round_robin    cycle through healthy replicas
  least_loaded   min(active + queued)
  dynamic        the paper's blueprint: concurrency < threshold -> route to
                 the "high_tp" replica class (few big replicas, best at small
                 batch); >= threshold -> the "high_replica" class (many small
                 replicas, best at high concurrency). Least-loaded inside the
                 chosen class; falls through to the other class if one is
                 empty/unhealthy.

Fault tolerance:
  - failover: when a replica dies, its in-flight requests (with partial
    generations) are resubmitted to healthy replicas and RESUME mid-stream
    (the engine re-prefills prompt+generated).
  - hedging: if a request produces no first token within ``hedge_after_s``,
    a shadow copy is dispatched to another replica; the first stream to
    produce tokens wins and the loser is cancelled.
"""
from __future__ import annotations

import copy
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional


from repro.core.engine import TokenEvent
from repro.core.metrics import Request, now
from repro.core.observability import MetricsSink, Tracer
from repro.core.replica import OnEvent, Replica


class NoReplicaAvailable(Exception):
    pass


@dataclass
class RouterConfig:
    policy: str = "least_loaded"            # round_robin | least_loaded | dynamic
    dynamic_threshold: int = 64             # paper §6: <64 -> high TP; >=64 -> replicas
    hedge_after_s: Optional[float] = None   # straggler hedging deadline (None = off)


class ReplicaRouter:
    def __init__(self, replicas: List[Replica], cfg: Optional[RouterConfig] = None,
                 sink: Optional[MetricsSink] = None,
                 tracer: Optional[Tracer] = None):
        self.replicas = list(replicas)
        self.cfg = cfg or RouterConfig()
        self.sink = sink or MetricsSink()
        self.tracer = tracer
        self._rr = 0
        self._lock = threading.Lock()
        self._live = 0                       # live concurrency estimate
        self._hedges: Dict[str, dict] = {}

    # ------------------------------------------------------------- selection
    def _healthy(self) -> List[Replica]:
        return [r for r in self.replicas if r.healthy]

    def add_replica(self, replica: Replica) -> None:
        """Elastic scale-out."""
        with self._lock:
            self.replicas.append(replica)

    def remove_replica(self, replica_id: str) -> None:
        """Elastic scale-in (drain is the caller's concern)."""
        with self._lock:
            self.replicas = [r for r in self.replicas if r.replica_id != replica_id]

    def select(self) -> Replica:
        healthy = self._healthy()
        if not healthy:
            raise NoReplicaAvailable("no healthy replicas")
        policy = self.cfg.policy
        if policy == "round_robin":
            with self._lock:
                r = healthy[self._rr % len(healthy)]
                self._rr += 1
            return r
        if policy == "dynamic":
            want = "high_tp" if self._live < self.cfg.dynamic_threshold else "high_replica"
            klass = [r for r in healthy if r.klass == want]
            pool = klass or healthy
            return min(pool, key=lambda r: r.load)
        return min(healthy, key=lambda r: r.load)

    # ------------------------------------------------------------- dispatch
    def submit(self, request: Request, on_event: OnEvent,
               replica: Optional[Replica] = None) -> Replica:
        t_route0 = now()
        if replica is None or not replica.healthy:
            replica = self.select()
        with self._lock:
            self._live += 1
        got_first = {"v": False}
        tracer = self.tracer

        def wrapped(ev: TokenEvent) -> None:
            got_first["v"] = True
            if ev.finished:
                with self._lock:
                    self._live -= 1
                self.sink.record_request(ev.request)
                if tracer:
                    # the request's span list is complete once its terminal
                    # event fires — export through the JSONL sink and drop
                    self.sink.record_trace(ev.request,
                                           tracer.pop(ev.request.req_id))
            on_event(ev)

        if tracer:
            tracer.add(request.req_id, "route", t_route0, now(),
                       replica=replica.replica_id, policy=self.cfg.policy)
        replica.submit(request, wrapped)
        self.sink.incr(f"routed_to.{replica.replica_id}")

        if self.cfg.hedge_after_s is not None:
            timer = threading.Timer(self.cfg.hedge_after_s,
                                    self._maybe_hedge, args=(request, replica, on_event, got_first))
            timer.daemon = True
            timer.start()
        return replica

    # ------------------------------------------------------------- hedging
    def _maybe_hedge(self, request: Request, primary: Replica, on_event: OnEvent,
                     got_first: dict) -> None:
        if got_first["v"] or request.finished or not primary.healthy:
            return
        others = [r for r in self._healthy() if r.replica_id != primary.replica_id]
        if not others:
            return
        shadow = copy.deepcopy(request)
        shadow.req_id = request.req_id + "#hedge"
        shadow.hedged = True
        request.hedged = True
        winner_decided = {"v": False}
        self.sink.incr("hedges")
        if self.tracer:
            self.tracer.event(request.req_id, "hedge", primary=primary.replica_id)

        def primary_guard(ev: TokenEvent) -> None:
            # primary finally produced output: cancel the shadow once
            if not winner_decided["v"]:
                winner_decided["v"] = True
                backup.cancel(shadow.req_id)
            on_event(ev)

        def shadow_events(ev: TokenEvent) -> None:
            if not winner_decided["v"]:
                winner_decided["v"] = True
                primary.cancel(request.req_id)
                self.sink.incr("hedge_wins")
            if ev.request.req_id.endswith("#hedge") and winner_decided["v"]:
                # merge shadow progress into the primary request object
                request.generated = ev.request.generated
                request.t2, request.t3 = ev.request.t2, ev.request.t3
                request.finished = ev.request.finished
                on_event(TokenEvent(request, ev.token, ev.t_emit, ev.finished))

        backup = min(others, key=lambda r: r.load)
        # swap the primary's callback path by resubmitting the guard on events
        # (simplification: the primary's wrapped callback already points at
        # on_event; the guard is applied to the shadow side)
        backup.submit(shadow, shadow_events)

    # ------------------------------------------------------------- failover
    def handle_failure(self, replica: Replica) -> int:
        """Re-dispatch a dead replica's in-flight requests; returns count."""
        orphans = replica.kill()
        n = 0
        for req, cb in orphans:
            req.finished = False
            try:
                target = self.select()
            except NoReplicaAvailable:
                req.error = "no replica for failover"
                continue
            target.submit(req, cb)
            self.sink.incr("failovers")
            if self.tracer:
                self.tracer.event(req.req_id, "failover",
                                  from_replica=replica.replica_id,
                                  to_replica=target.replica_id)
            n += 1
        return n

    def health_sweep(self) -> List[str]:
        """Mark watchdog-expired replicas unhealthy and fail them over."""
        failed = []
        for r in list(self.replicas):
            if r.healthy and r.watchdog_expired():
                self.handle_failure(r)
                failed.append(r.replica_id)
        return failed
