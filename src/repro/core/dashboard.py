"""Self-contained HTML / markdown dashboard rendered from a serving
timeline (``TimelineAggregator.timeline()`` + ``summary()``).

The HTML report is a single file with zero external assets: stat tiles for
the headline numbers, then one inline-SVG line chart per panel (TTFT, TBT,
throughput, queue depth, utilization, preemption/COW rates, SLO
attainment) with hover crosshair + tooltip, a legend for multi-series
panels, light/dark theming off ``prefers-color-scheme``, and a <details>
data table per chart as the accessible fallback. Colors are the validated
reference categorical palette (slots 1–3 only per panel) with chart chrome
in the documented ink roles; series identity is never carried by color
alone (legend + table view).
"""
from __future__ import annotations

import html
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

# Validated reference palette (dataviz reference instance): first three
# categorical slots (all-pairs safe in both modes), light / dark steps.
_SERIES_LIGHT = ["#2a78d6", "#eb6834", "#1baf7a"]
_SERIES_DARK = ["#3987e5", "#d95926", "#199e70"]

_CSS = """
:root { color-scheme: light dark; }
body.viz-root {
  color-scheme: light;
  --surface-1: #fcfcfb; --page: #f9f9f7;
  --text-primary: #0b0b0b; --text-secondary: #52514e; --muted: #898781;
  --grid: #e1e0d9; --axis: #c3c2b7; --border: rgba(11,11,11,0.10);
  --series-1: #2a78d6; --series-2: #eb6834; --series-3: #1baf7a;
  --good: #0ca30c; --critical: #d03b3b;
  margin: 0; background: var(--page); color: var(--text-primary);
  font: 14px/1.45 system-ui, -apple-system, "Segoe UI", sans-serif;
}
@media (prefers-color-scheme: dark) {
  body.viz-root {
    color-scheme: dark;
    --surface-1: #1a1a19; --page: #0d0d0d;
    --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --axis: #383835; --border: rgba(255,255,255,0.10);
    --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
  }
}
.wrap { max-width: 1180px; margin: 0 auto; padding: 24px 20px 48px; }
h1 { font-size: 20px; font-weight: 650; margin: 0 0 4px; }
.sub { color: var(--text-secondary); margin: 0 0 20px; font-size: 13px; }
.tiles { display: grid; grid-template-columns: repeat(auto-fill, minmax(160px, 1fr));
         gap: 12px; margin-bottom: 20px; }
.tile { background: var(--surface-1); border: 1px solid var(--border);
        border-radius: 10px; padding: 12px 14px; }
.tile .k { color: var(--text-secondary); font-size: 12px; }
.tile .v { font-size: 22px; font-weight: 650; margin-top: 2px; }
.tile .u { color: var(--muted); font-size: 12px; font-weight: 400; }
.grid2 { display: grid; grid-template-columns: repeat(auto-fit, minmax(420px, 1fr));
         gap: 16px; }
.panel { background: var(--surface-1); border: 1px solid var(--border);
         border-radius: 10px; padding: 14px 14px 8px; }
.panel h2 { font-size: 13px; font-weight: 650; margin: 0 0 2px; }
.panel .desc { color: var(--text-secondary); font-size: 12px; margin: 0 0 8px; }
.legend { display: flex; gap: 14px; font-size: 12px; color: var(--text-secondary);
          margin: 0 0 4px; flex-wrap: wrap; }
.legend .chip { display: inline-block; width: 10px; height: 10px;
                border-radius: 3px; margin-right: 5px; vertical-align: -1px; }
svg.chart { width: 100%; height: auto; display: block; }
svg.chart text { fill: var(--muted); font: 11px system-ui, sans-serif; }
.gridline { stroke: var(--grid); stroke-width: 1; }
.axisline { stroke: var(--axis); stroke-width: 1; }
.tooltip { position: fixed; pointer-events: none; background: var(--surface-1);
           border: 1px solid var(--border); border-radius: 8px;
           padding: 6px 10px; font-size: 12px; display: none; z-index: 10;
           box-shadow: 0 2px 10px rgba(0,0,0,0.15); }
.tooltip b { font-weight: 650; }
details { margin: 6px 0 8px; }
summary { color: var(--muted); font-size: 12px; cursor: pointer; }
table.data { border-collapse: collapse; font-size: 12px; margin-top: 6px;
             font-variant-numeric: tabular-nums; }
table.data th, table.data td { border: 1px solid var(--grid);
             padding: 3px 8px; text-align: right; color: var(--text-secondary); }
table.data th { color: var(--text-primary); font-weight: 600; }
"""

_JS = """
(function () {
  var tip = document.createElement('div');
  tip.className = 'tooltip';
  document.body.appendChild(tip);
  document.querySelectorAll('svg.chart').forEach(function (svg) {
    var data = JSON.parse(svg.getAttribute('data-points'));
    var x0 = +svg.getAttribute('data-x0'), x1 = +svg.getAttribute('data-x1');
    var cross = svg.querySelector('.crosshair');
    svg.addEventListener('mousemove', function (ev) {
      var r = svg.getBoundingClientRect();
      var fx = (ev.clientX - r.left) / r.width;
      var vw = svg.viewBox.baseVal;
      var px = fx * vw.width;
      if (px < x0 || px > x1 || !data.t.length) { return; }
      var frac = (px - x0) / (x1 - x0);
      var i = Math.round(frac * (data.t.length - 1));
      i = Math.max(0, Math.min(data.t.length - 1, i));
      var cx = x0 + (data.t.length > 1 ? i / (data.t.length - 1) : 0.5) * (x1 - x0);
      cross.setAttribute('x1', cx); cross.setAttribute('x2', cx);
      cross.style.display = 'block';
      var rows = '<b>t = ' + data.t[i].toFixed(1) + ' s</b>';
      data.series.forEach(function (s) {
        rows += '<br><span class="chip" style="background:' + s.color +
                '"></span>' + s.name + ': ' + s.fmt_values[i];
      });
      tip.innerHTML = rows;
      tip.style.display = 'block';
      tip.style.left = (ev.clientX + 14) + 'px';
      tip.style.top = (ev.clientY + 14) + 'px';
    });
    svg.addEventListener('mouseleave', function () {
      tip.style.display = 'none';
      cross.style.display = 'none';
    });
  });
})();
"""


def _fmt(v: Optional[float], unit: str = "") -> str:
    if v is None:
        return "–"
    if unit == "%":
        return f"{100.0 * v:.1f}%"
    if unit == "ms":
        return f"{1e3 * v:.1f} ms"
    if abs(v) >= 1000:
        return f"{v:,.0f}"
    if abs(v) >= 10:
        return f"{v:.1f}"
    return f"{v:.3g}"


def _polyline(xs: List[float], ys: List[float]) -> str:
    return " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(xs, ys))


def _chart(title: str, desc: str, t: List[float],
           series: Sequence[Tuple[str, int, List[Optional[float]], str]],
           *, y_max: Optional[float] = None) -> str:
    """One panel: ``series`` is (name, palette_slot_1based, values, unit).
    Gaps (None) break the polyline."""
    W, H = 560, 180
    PL, PR, PT, PB = 46, 10, 8, 22
    x0, x1 = PL, W - PR
    vals = [v for _, _, vs, _ in series for v in vs if v is not None]
    vmax = y_max if y_max is not None else (max(vals) if vals else 1.0)
    vmax = vmax if vmax > 0 else 1.0
    vmax *= 1.05
    t_span = (t[-1] - t[0]) if len(t) > 1 else 1.0

    def sx(i: int) -> float:
        if len(t) <= 1:
            return (x0 + x1) / 2
        return x0 + (t[i] - t[0]) / t_span * (x1 - x0)

    def sy(v: float) -> float:
        return PT + (1.0 - min(v, vmax) / vmax) * (H - PT - PB)

    parts = []
    for k in range(4):                         # recessive horizontal grid
        gy = PT + k / 3 * (H - PT - PB)
        gv = vmax * (1 - k / 3)
        parts.append(f'<line class="gridline" x1="{x0}" y1="{gy:.1f}" '
                     f'x2="{x1}" y2="{gy:.1f}"/>')
        parts.append(f'<text x="{x0 - 6}" y="{gy + 3.5:.1f}" '
                     f'text-anchor="end">{_fmt(gv)}</text>')
    parts.append(f'<line class="axisline" x1="{x0}" y1="{H - PB}" '
                 f'x2="{x1}" y2="{H - PB}"/>')
    parts.append(f'<text x="{x0}" y="{H - 6}">{t[0]:.0f}s</text>')
    parts.append(f'<text x="{x1}" y="{H - 6}" text-anchor="end">{t[-1]:.0f}s</text>')
    for name, slot, vs, unit in series:
        run_x: List[float] = []
        run_y: List[float] = []
        runs = []
        for i, v in enumerate(vs):
            if v is None:
                if run_x:
                    runs.append((run_x, run_y))
                    run_x, run_y = [], []
                continue
            run_x.append(sx(i))
            run_y.append(sy(v))
        if run_x:
            runs.append((run_x, run_y))
        for rx, ry in runs:
            if len(rx) == 1:
                parts.append(f'<circle cx="{rx[0]:.1f}" cy="{ry[0]:.1f}" r="2.5" '
                             f'fill="var(--series-{slot})"/>')
            else:
                parts.append(f'<polyline points="{_polyline(rx, ry)}" fill="none" '
                             f'stroke="var(--series-{slot})" stroke-width="2" '
                             f'stroke-linejoin="round" stroke-linecap="round"/>')
    parts.append(f'<line class="crosshair" x1="0" y1="{PT}" x2="0" y2="{H - PB}" '
                 f'stroke="var(--muted)" stroke-width="1" stroke-dasharray="3 3" '
                 f'style="display:none"/>')

    colors = {1: _SERIES_LIGHT[0], 2: _SERIES_LIGHT[1], 3: _SERIES_LIGHT[2]}
    payload = {
        "t": [round(x, 3) for x in t],
        "series": [{
            "name": name, "color": colors[slot],
            "fmt_values": [_fmt(v, unit) for v in vs],
        } for name, slot, vs, unit in series],
    }
    legend = ""
    if len(series) > 1:
        legend = '<div class="legend">' + "".join(
            f'<span><span class="chip" style="background:var(--series-{slot})">'
            f'</span>{html.escape(name)}</span>'
            for name, slot, _, _ in series) + "</div>"
    head = ["t_s"] + [name for name, _, _, _ in series]
    rows = "".join(
        "<tr><td>" + f"{t[i]:.1f}</td>" + "".join(
            f"<td>{_fmt(vs[i], unit)}</td>" for _, _, vs, unit in series)
        + "</tr>"
        for i in range(len(t)))
    table = (f'<details><summary>data table</summary><table class="data">'
             f'<tr>{"".join(f"<th>{html.escape(h)}</th>" for h in head)}</tr>'
             f"{rows}</table></details>")
    return (
        f'<div class="panel"><h2>{html.escape(title)}</h2>'
        f'<p class="desc">{html.escape(desc)}</p>{legend}'
        f'<svg class="chart" viewBox="0 0 {W} {H}" data-x0="{x0}" data-x1="{x1}" '
        f"data-points='{json.dumps(payload)}'>{''.join(parts)}</svg>"
        f"{table}</div>")


def _tile(label: str, value: str, unit: str = "") -> str:
    u = f' <span class="u">{html.escape(unit)}</span>' if unit else ""
    return (f'<div class="tile"><div class="k">{html.escape(label)}</div>'
            f'<div class="v">{html.escape(value)}{u}</div></div>')


def _col(timeline: List[Dict[str, Any]], key: str) -> List[Optional[float]]:
    return [w.get(key) for w in timeline]


def render_dashboard(timeline: List[Dict[str, Any]], summary: Dict[str, Any],
                     title: str = "Serving timeline") -> str:
    """Render the full HTML dashboard (a single self-contained page)."""
    t = [float(w["t"]) for w in timeline]
    slo = summary.get("slo", {})
    slo_txt = (f"TTFT ≤ {_fmt(slo.get('ttft_target_s'), 'ms')}, "
               f"TBT ≤ {_fmt(slo.get('tbt_target_s'), 'ms')}")
    tiles = "".join([
        _tile("Requests", _fmt(summary.get("n_requests"))),
        _tile("Throughput", _fmt(summary.get("throughput_tok_s")), "tok/s"),
        _tile("p50 TTFT", _fmt(summary.get("p50_ttft_s"), "ms")),
        _tile("p99 TTFT", _fmt(summary.get("p99_ttft_s"), "ms")),
        _tile("p50 TBT", _fmt(summary.get("p50_tbt_s"), "ms")),
        _tile("p99 TBT", _fmt(summary.get("p99_tbt_s"), "ms")),
        _tile("SLO attainment", _fmt(summary.get("slo_attainment"), "%")),
        _tile("Preemptions", _fmt(summary.get("preemptions"))),
        _tile("Shed", _fmt(summary.get("shed", 0))),
        _tile("Failovers", _fmt(summary.get("failovers", 0))),
    ])
    charts = "".join([
        _chart("TTFT", "time to first token per completion window", t, [
            ("p50", 1, _col(timeline, "p50_ttft_s"), "ms"),
            ("p99", 2, _col(timeline, "p99_ttft_s"), "ms")]),
        _chart("TBT", "time between tokens (seconds/token)", t, [
            ("p50", 1, _col(timeline, "p50_tbt_s"), "ms"),
            ("p99", 2, _col(timeline, "p99_tbt_s"), "ms")]),
        _chart("Throughput", "tokens fed per second (prefill + decode + drafts)",
               t, [
            ("total", 1, _col(timeline, "throughput_tok_s"), ""),
            ("decode", 3, _col(timeline, "decode_tok_s"), "")]),
        _chart("Queue", "requests waiting for a slot", t, [
            ("mean depth", 1, _col(timeline, "queue_depth_mean"), ""),
            ("max depth", 2,
             [float(v) if v is not None else None
              for v in _col(timeline, "queue_depth_max")], "")]),
        _chart("Queue wait", "router arrival to engine admission", t, [
            ("p50", 1, _col(timeline, "p50_queue_wait_s"), "ms"),
            ("p99", 2, _col(timeline, "p99_queue_wait_s"), "ms")]),
        _chart("Utilization", "batch occupancy / token-budget fill / KV pages",
               t, [
            ("slots", 1, _col(timeline, "occupancy_frac"), "%"),
            ("budget", 2, _col(timeline, "budget_util"), "%"),
            ("kv", 3, _col(timeline, "kv_util_mean"), "%")], y_max=1.0),
        _chart("Disruption", "preemptions and COW page copies per second", t, [
            ("preempt/s", 1, _col(timeline, "preemptions_per_s"), ""),
            ("cow pages/s", 2, _col(timeline, "cow_pages_per_s"), "")]),
        _chart("SLO attainment", f"fraction of completions meeting {slo_txt}",
               t, [("attained", 1, _col(timeline, "slo_attainment"), "%")],
               y_max=1.0),
        _chart("Resilience", "load shedding, submit retries, deadline"
               " cancellations, and replica failovers per window", t, [
            ("shed", 1,
             [float(v) if v is not None else None
              for v in _col(timeline, "shed")], ""),
            ("retries", 2,
             [float(v) if v is not None else None
              for v in _col(timeline, "retries")], ""),
            ("failovers", 3,
             [float(v) if v is not None else None
              for v in _col(timeline, "failovers")], "")]),
    ])
    return f"""<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>{html.escape(title)}</title><style>{_CSS}</style></head>
<body class="viz-root"><div class="wrap">
<h1>{html.escape(title)}</h1>
<p class="sub">{summary.get('n_windows', 0)} windows of
{_fmt(summary.get('window_s'))} s · {summary.get('n_steps', 0)} engine
iterations · SLO: {html.escape(slo_txt)}</p>
<div class="tiles">{tiles}</div>
<div class="grid2">{charts}</div>
</div><script>{_JS}</script></body></html>
"""


def render_markdown(timeline: List[Dict[str, Any]], summary: Dict[str, Any],
                    title: str = "Serving timeline") -> str:
    """Compact markdown twin of the HTML dashboard (for logs / PR bodies)."""
    lines = [f"# {title}", ""]
    lines.append(f"- requests: {summary.get('n_requests')}  "
                 f"(over {summary.get('n_windows')} x "
                 f"{summary.get('window_s')}s windows, "
                 f"{summary.get('n_steps')} engine iterations)")
    lines.append(f"- throughput: {_fmt(summary.get('throughput_tok_s'))} tok/s")
    lines.append(f"- TTFT p50/p99: {_fmt(summary.get('p50_ttft_s'), 'ms')} / "
                 f"{_fmt(summary.get('p99_ttft_s'), 'ms')}")
    lines.append(f"- TBT p50/p99: {_fmt(summary.get('p50_tbt_s'), 'ms')} / "
                 f"{_fmt(summary.get('p99_tbt_s'), 'ms')}")
    lines.append(f"- SLO attainment: {_fmt(summary.get('slo_attainment'), '%')} "
                 f"(targets: TTFT {_fmt(summary.get('slo', {}).get('ttft_target_s'), 'ms')}, "
                 f"TBT {_fmt(summary.get('slo', {}).get('tbt_target_s'), 'ms')})")
    lines.append(f"- preemptions: {summary.get('preemptions')}")
    lines += ["", "| t(s) | done | tok/s | p50 TTFT | p99 TTFT | queue | "
                  "occ | kv | SLO |",
              "|---:|---:|---:|---:|---:|---:|---:|---:|---:|"]
    for w in timeline:
        lines.append(
            f"| {w['t']:.1f} | {w['completed']} "
            f"| {_fmt(w['throughput_tok_s'])} "
            f"| {_fmt(w['p50_ttft_s'], 'ms')} | {_fmt(w['p99_ttft_s'], 'ms')} "
            f"| {_fmt(w['queue_depth_mean'])} | {_fmt(w['occupancy_frac'], '%')} "
            f"| {_fmt(w['kv_util_mean'], '%')} "
            f"| {_fmt(w['slo_attainment'], '%')} |")
    return "\n".join(lines) + "\n"
