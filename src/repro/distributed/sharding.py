"""Logical-axis sharding rules with divisibility fallback.

Every parameter/cache/activation dim carries a *logical* axis name; a RuleSet
maps logical names to ordered candidate mesh-axis assignments. A mesh axis is
assigned to a dim only if (a) it exists in the mesh, (b) it is not already
used by another dim of the same tensor, and (c) its size divides the dim.
Assignment order follows per-name priority (e.g. kv_heads outranks kv_seq, so
a GQA cache shards heads first and falls back to sequence sharding only when
the head count doesn't divide — the flash-decode style layout).

This is how qwen's kv=2 ends up replicated across model=16 while gemma2's
kv=16 shards exactly, with zero per-arch code.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamSpec

Candidate = Tuple[str, ...]          # one candidate = tuple of mesh axes used together


@dataclass(frozen=True)
class RuleSet:
    rules: Dict[str, Tuple[Candidate, ...]]
    priority: Dict[str, int]
    name: str = "custom"

    def candidates(self, logical: str) -> Tuple[Candidate, ...]:
        return self.rules.get(logical, ())

    def prio(self, logical: str) -> int:
        return self.priority.get(logical, 0)


_PRIORITY = {
    "experts": 10, "heads": 10, "kv_heads": 10,
    "expert_mlp": 9, "mlp": 9, "vocab": 9, "ssm_proj": 9, "ssm_inner": 9,
    "conv_dim": 9, "ssm_heads": 9,
    "batch": 8,
    "embed": 5,
    "kv_seq": 3, "seq": 2,
    "layers": 0, "head_dim": 0, "vision_patch": 0,
}


def make_rules(mode: str = "serve", moe: str = "ep", *, multi_pod: bool = False,
               seq_shard: bool = False, tensor_axis: str = "model",
               expert_axis: Optional[str] = None) -> RuleSet:
    """mode: "serve" | "train".  moe: "ep" (hybrid TPxEP — experts on the
    expert axis, the paper's optimized config) | "tp" (paper-baseline pure TP
    — experts replicated). On the fixed production mesh the expert axis IS
    the data axis; the factored Exp4 mesh ("data","expert","tensor") names
    them explicitly."""
    t = tensor_axis
    e = expert_axis or "data"
    if multi_pod:
        batch: Tuple[Candidate, ...] = (("pod", "data"), ("data",))
    else:
        batch = (("data",),)
    r: Dict[str, Tuple[Candidate, ...]] = {
        "batch": batch,
        "heads": ((t,),),
        "kv_heads": ((t,),),
        "mlp": ((t,),),
        "expert_mlp": ((t,),),
        "vocab": ((t,),),
        "experts": ((e,),) if moe == "ep" else (),
        # kv_seq: fallback when kv_heads can't take the tensor axis; on the
        # factored Exp4 mesh it may also spill onto the expert axis (decode
        # attention handles seq-sharded caches via softmax-combine collectives)
        "kv_seq": ((t,), (e,)) if expert_axis else ((t,),),
        "ssm_proj": ((t,),),
        "ssm_inner": ((t,),),
        "conv_dim": ((t,),),
        "ssm_heads": ((t,),),
    }
    if mode == "train":
        r["embed"] = (("data",),)             # FSDP within pod
    if seq_shard:
        r["seq"] = ((t,),)                    # sequence parallelism (hillclimb)
    return RuleSet(rules=r, priority=dict(_PRIORITY), name=f"{mode}/{moe}/{t}")


def partition_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                   mesh: Mesh, ruleset: RuleSet) -> P:
    """Build a PartitionSpec for `shape` with divisibility + axis-reuse checks."""
    assert len(shape) == len(logical), (shape, logical)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assign: Dict[int, Tuple[str, ...]] = {}
    used: set = set()
    order = sorted(range(len(shape)),
                   key=lambda i: -ruleset.prio(logical[i]) if logical[i] else 1)
    for i in order:
        name = logical[i]
        if name is None:
            continue
        for cand in ruleset.candidates(name):
            if any(a not in mesh_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = int(np.prod([mesh_sizes[a] for a in cand]))
            if prod > 1 and shape[i] % prod == 0:
                assign[i] = cand
                used.update(cand)
                break
    entries = []
    for i in range(len(shape)):
        if i in assign:
            entries.append(assign[i] if len(assign[i]) > 1 else assign[i][0])
        else:
            entries.append(None)
    return P(*entries)


# --------------------------------------------------------------------------
# Active context (thread-local): installs (mesh, ruleset) so model code can
# call ``constrain`` without threading sharding through every function.
# --------------------------------------------------------------------------
class _Active(threading.local):
    mesh: Optional[Mesh] = None
    ruleset: Optional[RuleSet] = None


_active = _Active()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], ruleset: Optional[RuleSet]):
    prev = (_active.mesh, _active.ruleset)
    _active.mesh, _active.ruleset = mesh, ruleset
    try:
        yield
    finally:
        _active.mesh, _active.ruleset = prev


def constrain(x, logical: Sequence[Optional[str]]):
    if _active.mesh is None or _active.ruleset is None:
        return x
    spec = partition_spec(x.shape, logical, _active.mesh, _active.ruleset)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_active.mesh, spec))


# --------------------------------------------------------------------------
# Whole-tree spec builders
# --------------------------------------------------------------------------
def param_partition_specs(spec_tree, mesh: Mesh, ruleset: RuleSet):
    """ParamSpec tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda s: partition_spec(s.shape, s.logical, mesh, ruleset),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


_CACHE_LOGICAL = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "slot_pos": ("layers", "batch", "kv_seq"),
    "kp": ("layers", None, None, "kv_heads", None),
    "vp": ("layers", None, None, "kv_heads", None),
    "ck": ("layers", "batch", None, "kv_heads", None),
    "cv": ("layers", "batch", None, "kv_heads", None),
    "state": ("layers", "batch", "ssm_heads", None, None),
    "conv": ("layers", "batch", "conv_dim", None),
}


def cache_partition_specs(cache_shapes, mesh: Mesh, ruleset: RuleSet):
    """Map an (abstract) cache tree to PartitionSpecs by leaf name."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if key in _CACHE_LOGICAL and hasattr(val, "shape"):
                    out[key] = partition_spec(val.shape, _CACHE_LOGICAL[key], mesh, ruleset)
                else:
                    out[key] = walk(val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        if hasattr(node, "shape"):   # unnamed leaf
            return P()
        return node
    return walk(cache_shapes)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
