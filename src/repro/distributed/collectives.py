"""Distributed-optimization helpers.

int8 gradient compression with error feedback (1000+-node training trick):
gradients are quantized to int8 (per-leaf absmax scale) before the data-axis
all-reduce; the quantization residual is carried to the next step so the
compression is unbiased in the long run. Cuts the gradient all-reduce bytes
4x (f32->int8), which moves the collective roofline term directly.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def compress_int8(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads, error):
    """Returns (quantized pytree of (q, scale), new_error pytree).
    error is the running residual (same tree as grads; zeros initially)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = compress_int8(g)
        deq = decompress_int8(q, scale)
        return (q, scale), g - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = treedef.unflatten([o[0] for o in out])
    etree = treedef.unflatten([o[1] for o in out])
    return qtree, etree


def decompress_grads(qtree):
    def is_pair(x):
        return isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")
    return jax.tree.map(lambda pair: decompress_int8(*pair), qtree, is_leaf=is_pair)


def zeros_error_like(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
