"""Checkpointing: sharded-npz snapshots with manifest, atomic publish, and an
async writer thread (no orbax offline — built from scratch).

Layout:  <dir>/step_<N>/shard_<i>.npz + manifest.json, published by writing
to step_<N>.tmp.<pid> and os.rename'ing — a reader never observes a partial
checkpoint, and a crash mid-save leaves the previous step intact
(checkpoint/restart fault tolerance).

Restore is mesh-agnostic: arrays are saved unsharded per leaf (CPU repo) or
per-shard chunks keyed by flat index; ``restore_checkpoint`` reassembles and
the caller re-applies device placement/sharding (reshard-on-load).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, *, shard_size: int = 128,
                    extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint write. Returns the published path."""
    leaves = _flatten_with_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
    shard: Dict[str, np.ndarray] = {}
    shard_idx = 0

    def flush():
        nonlocal shard, shard_idx
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_idx:05d}.npz"), **shard)
            shard = {}
            shard_idx += 1

    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        # npz keys cannot contain '/', map to a safe name
        safe = f"leaf_{i:06d}"
        manifest["leaves"].append({
            "key": key, "npz_key": safe, "shard": shard_idx,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        })
        shard[safe] = arr
        if len(shard) >= shard_size:
            flush()
    flush()

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``. Returns (tree, step).
    Raises FileNotFoundError if no checkpoint exists."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_shard: Dict[int, List[dict]] = {}
    for entry in manifest["leaves"]:
        by_shard.setdefault(entry["shard"], []).append(entry)
    values: Dict[str, np.ndarray] = {}
    for shard_idx, entries in by_shard.items():
        with np.load(os.path.join(path, f"shard_{shard_idx:05d}.npz")) as z:
            for e in entries:
                values[e["key"]] = z[e["npz_key"]]

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key not in values:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = values[key]
        want = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, f"{key}: ckpt {arr.shape} vs model {want}"
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer: snapshot-to-host happens on the
    caller's thread (cheap on CPU; device->host on TPU), serialization and
    disk I/O are off the training loop."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree, extra_meta=None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            self.last_path = save_checkpoint(self.directory, step, host_tree,
                                             extra_meta=extra_meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory)) if m
        )
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
