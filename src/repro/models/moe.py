"""Mixture-of-Experts layer — the substrate for the paper's Exp4 study.

Four execution strategies (RunCtx.moe_strategy):

  "dropless"     exact token-choice routing: sort by expert + ragged gmm
                 (Pallas kernel on TPU). Used by the serving engine.
  "capacity"     local capacity-buffer dispatch (scatter, no giant one-hot
                 einsum) + dense per-expert matmuls. Pure-local: used on CPU
                 tests and as the building block of the sharded paths.
  "tp_shardmap"  the paper's *baseline* "original TP solution": experts
                 replicated across the data axis, expert FFN sharded on the
                 model axis; down-proj partials psum over TP. No all-to-all.
  "ep_shardmap"  the paper's *hybrid TP x EP*: experts sharded over the EP
                 axis (all-to-all dispatch/return), expert FFN sharded over
                 the TP axis. Explicit lax.all_to_all => collective bytes are
                 visible to the roofline.

All strategies share the router and are validated against each other.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.moe_gmm import gmm
from repro.models.common import RunCtx, act_fn


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# --------------------------------------------------------------------------
# Router
# --------------------------------------------------------------------------
def router_topk(xf, router_w, k: int):
    """xf (T, d) -> (topw (T,k) f32, topi (T,k) i32, aux scalar)."""
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(axis=1), axis=0) / k
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pmean)
    return topw, topi.astype(jnp.int32), aux


# --------------------------------------------------------------------------
# Capacity dispatch/combine (scatter-based; no (T,E,C) one-hot einsum)
# --------------------------------------------------------------------------
def capacity_dispatch(xf, topi, E: int, cap: int):
    T, K = topi.shape
    e = topi.reshape(-1)                                       # (TK,)
    oh = jax.nn.one_hot(e, E, dtype=jnp.int32)                 # id >= E (trash) -> all-zero row
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1    # (TK,) slot in expert
    keep = (pos >= 0) & (pos < cap)
    e_safe = jnp.where(keep, e, E)                             # trash row E
    p_safe = jnp.where(keep, pos, 0)
    tok = jnp.arange(T * K) // K
    ebuf = jnp.zeros((E + 1, cap, xf.shape[-1]), xf.dtype).at[e_safe, p_safe].set(xf[tok])
    return ebuf[:E], (e_safe, p_safe, keep)


def capacity_combine(ye, info, topw):
    """ye (E, cap, d) expert outputs -> (T, d) weighted combine."""
    e_safe, p_safe, keep = info
    T, K = topw.shape
    ybuf = jnp.concatenate([ye, jnp.zeros((1,) + ye.shape[1:], ye.dtype)], axis=0)
    rows = ybuf[e_safe, p_safe].astype(jnp.float32)            # (TK, d)
    w = topw.reshape(-1)[:, None] * keep[:, None]
    return (rows * w).reshape(T, K, -1).sum(axis=1)


def expert_ffn_dense(ebuf, wg, wu, wd):
    """(E, C, d) x (E, d, f) -> (E, C, d). Dense, MXU-aligned."""
    h1 = jnp.einsum("ecd,edf->ecf", ebuf, wg)
    h2 = jnp.einsum("ecd,edf->ecf", ebuf, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h2, wd)


def _shared_ffn(p_shared, xf, act_name):
    h = jnp.einsum("td,df->tf", xf, p_shared["wi"])
    g = jnp.einsum("td,df->tf", xf, p_shared["wg"])
    return jnp.einsum("tf,fd->td", act_fn(act_name)(g) * h, p_shared["wo"])


# --------------------------------------------------------------------------
# Strategy: dropless (sort + ragged gmm) — serving engine path
# --------------------------------------------------------------------------
def moe_dropless(p, xf, cfg: ModelConfig, ctx: RunCtx):
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T, d = xf.shape
    topw, topi, aux = router_topk(xf, p["router"], K)
    e = topi.reshape(-1)
    tok = jnp.arange(T * K) // K
    order = jnp.argsort(e)
    xs = xf[tok[order]]
    gs = jnp.bincount(e, length=E).astype(jnp.int32)
    backend = "pallas" if ctx.attn_backend == "pallas" else "xla"
    h1 = gmm(xs, p["wg"], gs, backend=backend, interpret=ctx.interpret)
    h2 = gmm(xs, p["wu"], gs, backend=backend, interpret=ctx.interpret)
    ys = gmm((jax.nn.silu(h1.astype(jnp.float32)) * h2.astype(jnp.float32)).astype(xs.dtype),
             p["wd"], gs, backend=backend, interpret=ctx.interpret)
    w_flat = topw.reshape(-1)[order]
    y = jnp.zeros((T, d), jnp.float32).at[tok[order]].add(ys.astype(jnp.float32) * w_flat[:, None])
    if "shared" in p:
        y = y + _shared_ffn(p["shared"], xf, cfg.act).astype(jnp.float32)
    return y.astype(xf.dtype), aux


# --------------------------------------------------------------------------
# Strategy: capacity (pure local)
# --------------------------------------------------------------------------
def moe_capacity(p, xf, cfg: ModelConfig, ctx: RunCtx):
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T, d = xf.shape
    cap = _round_up(max(int(math.ceil(T * K / E * m.capacity_factor)), 8), 8)
    topw, topi, aux = router_topk(xf, p["router"], K)
    ebuf, info = capacity_dispatch(xf, topi, E, cap)
    ye = expert_ffn_dense(ebuf, p["wg"], p["wu"], p["wd"])
    y = capacity_combine(ye, info, topw)
    if "shared" in p:
        y = y + _shared_ffn(p["shared"], xf, cfg.act).astype(jnp.float32)
    return y.astype(xf.dtype), aux


# --------------------------------------------------------------------------
# Strategies: tp_shardmap / ep_shardmap (explicit collectives)
# --------------------------------------------------------------------------
def _moe_local_tp(xf, router_w, wg, wu, wd, shared, cfg, tp_axis, cf):
    """Inside shard_map: experts REPLICATED on the ep axis, FFN dim sharded on
    tp. xf (T_l, d). Down-proj partials psum over tp."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T = xf.shape[0]
    cap = _round_up(max(int(math.ceil(T * K / E * cf)), 8), 8)
    topw, topi, aux = router_topk(xf, router_w, K)
    ebuf, info = capacity_dispatch(xf, topi, E, cap)
    h1 = jnp.einsum("ecd,edf->ecf", ebuf, wg)
    h2 = jnp.einsum("ecd,edf->ecf", ebuf, wu)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h2, wd)     # partial over f
    ye = jax.lax.psum(ye, tp_axis)
    y = capacity_combine(ye, info, topw)
    if shared is not None:
        hs = jnp.einsum("td,df->tf", xf, shared["wi"])
        gs_ = jnp.einsum("td,df->tf", xf, shared["wg"])
        ys = jnp.einsum("tf,fd->td", act_fn(cfg.act)(gs_) * hs, shared["wo"])
        y = y + jax.lax.psum(ys, tp_axis).astype(jnp.float32)
    return y.astype(xf.dtype), aux


def _a2a_int8(buf, axis_name):
    """int8-compressed all-to-all (beyond-paper): quantize rows per-row
    absmax, exchange int8 payload + f32 scales — halves the dispatch bytes on
    the ICI. Exact to ~0.4% per row (validated in tests)."""
    amax = jnp.max(jnp.abs(buf.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(buf.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    q = jax.lax.all_to_all(q, axis_name, 0, 0)
    scale = jax.lax.all_to_all(scale, axis_name, 0, 0)
    return (q.astype(jnp.float32) * scale).astype(buf.dtype)


def _moe_local_ep(xf, router_w, wg, wu, wd, shared, cfg, ep_axis, tp_axis, cf,
                  a2a_quant: bool = False):
    """Inside shard_map: hybrid TP x EP. Experts sharded on ep axis (explicit
    all-to-all dispatch/return), FFN dim sharded on tp axis."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    dp = jax.lax.axis_size(ep_axis)
    E_l = E // dp
    T, d = xf.shape
    topw, topi, aux = router_topk(xf, router_w, K)
    e = topi.reshape(-1)                                          # (TK,) global expert
    tok = jnp.arange(T * K) // K

    # --- stage 1: dispatch to the shard owning each expert ------------------
    dest = e // E_l
    ohd = jax.nn.one_hot(dest, dp, dtype=jnp.int32)
    posd = jnp.sum(jnp.cumsum(ohd, axis=0) * ohd, axis=-1) - 1
    # min-capacity 4 (not 8): at decode (few tokens/device) the dispatch is
    # padding-dominated — §Perf cell A iter 4 measured the a2a halving.
    cap_s = _round_up(max(int(math.ceil(T * K * cf / dp)), 4), 4)
    keep = posd < cap_s
    d_safe = jnp.where(keep, dest, dp)
    p_safe = jnp.where(keep, posd, 0)
    sx = jnp.zeros((dp + 1, cap_s, d), xf.dtype).at[d_safe, p_safe].set(xf[tok])
    se = jnp.zeros((dp + 1, cap_s), jnp.int32).at[d_safe, p_safe].set((e % E_l).astype(jnp.int32))
    sv = jnp.zeros((dp + 1, cap_s), jnp.int32).at[d_safe, p_safe].set(keep.astype(jnp.int32))
    if a2a_quant:
        rx = _a2a_int8(sx[:dp], ep_axis)                          # (dp, cap_s, d)
    else:
        rx = jax.lax.all_to_all(sx[:dp], ep_axis, 0, 0)
    re = jax.lax.all_to_all(se[:dp], ep_axis, 0, 0)
    rv = jax.lax.all_to_all(sv[:dp], ep_axis, 0, 0)

    # --- stage 2: local expert FFN over received rows (capacity buffers) ----
    R = dp * cap_s
    rxf, ref_, rvf = rx.reshape(R, d), re.reshape(R), rv.reshape(R) > 0
    e2 = jnp.where(rvf, ref_, E_l)                                # invalid -> trash id
    cap2 = _round_up(max(int(math.ceil(R / E_l * cf)), 8), 8)
    ebuf2, info2 = capacity_dispatch(rxf, e2[:, None], E_l, cap2)
    h1 = jnp.einsum("ecd,edf->ecf", ebuf2, wg)                    # f_l local (TP)
    h2 = jnp.einsum("ecd,edf->ecf", ebuf2, wu)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h2, wd)     # partial over f
    ye = jax.lax.psum(ye, tp_axis)
    y_rows = capacity_combine(ye, info2, jnp.ones((R, 1), jnp.float32))   # (R, d) f32
    ybuf = y_rows.reshape(dp, cap_s, d).astype(xf.dtype)

    # --- stage 3: return + combine ------------------------------------------
    if a2a_quant:
        yret = _a2a_int8(ybuf, ep_axis)                           # rows for my sends
    else:
        yret = jax.lax.all_to_all(ybuf, ep_axis, 0, 0)
    yret = jnp.concatenate([yret, jnp.zeros((1, cap_s, d), yret.dtype)], axis=0)
    rows = yret[d_safe, p_safe].astype(jnp.float32)               # (TK, d)
    w = topw.reshape(-1)[:, None] * keep[:, None]
    y = (rows * w).reshape(T, K, d).sum(axis=1)
    if shared is not None:
        hs = jnp.einsum("td,df->tf", xf, shared["wi"])
        gs_ = jnp.einsum("td,df->tf", xf, shared["wg"])
        ys2 = jnp.einsum("tf,fd->td", act_fn(cfg.act)(gs_) * hs, shared["wo"])
        y = y + jax.lax.psum(ys2, tp_axis).astype(jnp.float32)
    return y.astype(xf.dtype), aux


def moe_sublayer(p: Dict[str, Any], h, cfg: ModelConfig, ctx: RunCtx) -> Tuple[Any, Any]:
    """h: (B, S, d) normed input. Returns (out (B,S,d), aux loss scalar)."""
    B, S, d = h.shape
    xf = h.reshape(B * S, d)
    strategy = ctx.moe_strategy
    if strategy in ("dropless", "capacity") or ctx.mesh is None:
        fn = moe_dropless if strategy == "dropless" else moe_capacity
        y, aux = fn(p, xf, cfg, ctx)
        return y.reshape(B, S, d), aux

    from jax.experimental.shard_map import shard_map

    mesh = ctx.mesh
    ep_ax, tp_ax = ctx.ep_axis, ctx.tp_axis
    dp = mesh.shape[ep_ax]
    m = cfg.moe
    # batch shards on the mesh's data axis when divisible; otherwise tokens
    # replicated (decode at B=1). On the fixed production mesh the data axis
    # IS the ep axis; on the factored Exp4 mesh they differ.
    b_ax = "data" if "data" in mesh.axis_names else ep_ax
    bsz = mesh.shape[b_ax]
    bspec = b_ax if (B % bsz == 0 and B >= bsz) else None
    ep = strategy == "ep_shardmap" and m.num_experts % dp == 0 and m.num_experts >= dp
    espec = ep_ax if ep else None   # experts dim of wg/wu/wd

    shared = p.get("shared")
    shared_specs = (
        {"wi": P(None, tp_ax), "wg": P(None, tp_ax), "wo": P(tp_ax, None)}
        if shared is not None else None
    )
    in_specs = (
        P(bspec, None, None),                 # x (B,S,d)
        P(None, None),                        # router
        P(espec, None, tp_ax),                # wg (E, d, f)
        P(espec, None, tp_ax),                # wu
        P(espec, tp_ax, None),                # wd (E, f, d)
        shared_specs,
    )
    out_specs = (P(bspec, None, None), P())

    def local(x_l, router_w, wg, wu, wd, shared_l):
        xf_l = x_l.reshape(-1, d)
        if ep:
            y, aux = _moe_local_ep(xf_l, router_w, wg, wu, wd, shared_l, cfg,
                                   ep_ax, tp_ax, m.capacity_factor,
                                   a2a_quant=ctx.quant == "a2a_int8")
        else:
            y, aux = _moe_local_tp(xf_l, router_w, wg, wu, wd, shared_l, cfg,
                                   tp_ax, m.capacity_factor)
        aux = jax.lax.pmean(jax.lax.pmean(aux, ep_ax), tp_ax)
        return y.reshape(x_l.shape), aux

    y, aux = shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False,
    )(h, p["router"], p["wg"], p["wu"], p["wd"], shared)
    return y, aux
