"""The LM: scanned layer-group decoder covering every assigned family.

dense / moe   : decoder-only, GQA attention, SwiGLU or MoE MLP
ssm           : mamba2 (attention-free)
hybrid        : jamba (period-8 mamba/attention pattern, alternating MoE)
encdec        : seamless (bidirectional encoder + cross-attention decoder)
vlm           : phi-3-vision (patch embeddings prepended via a real projector)

Repeated layers are stacked and executed with ``lax.scan`` over the group's
repeats (small HLO, fast 512-device compiles, remat-friendly). The decode path
consumes either dense ring-buffer caches or the paged KV pool.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerGroup, ModelConfig
from repro.models import params as params_lib
from repro.models.attention import attention_sublayer
from repro.models.common import RunCtx, dense_mlp, rmsnorm, shard_act
from repro.models.mamba import mamba_sublayer
from repro.models.moe import moe_sublayer


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ params
    def param_specs(self):
        return params_lib.param_specs(self.cfg)

    def init_params(self, rng, dtype=jnp.float32):
        return params_lib.init_params(self.cfg, rng, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return params_lib.abstract_params(self.cfg, dtype)

    # ------------------------------------------------------------------ layers
    def _apply_layer(self, p, x, c, *, kind: str, ctx: RunCtx,
                     positions, memory, page_table, lengths, chunk=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_c: Dict[str, Any] = {} if c is not None else None

        # sequence-parallel placement: constraining each sublayer OUTPUT to
        # the seq-sharded layout (before the residual add) lets GSPMD turn the
        # TP partial-sum all-reduce into a reduce-scatter (no-op when the
        # "seq" rule is off).
        seq_sharded = ("batch", "seq", None)
        h = rmsnorm(x, p["ln1"], cfg.rms_eps)
        if kind == "M":
            sub, cm = mamba_sublayer(p["ssm"], h, cfg, ctx,
                                     cache=c.get("ssm") if c else None,
                                     chunk=chunk)
            if new_c is not None:
                new_c["ssm"] = cm
        else:
            sub, ca = attention_sublayer(
                p["attn"], h, ctx, cfg, kind,
                cache=c.get("attn") if c else None,
                positions=positions, page_table=page_table, lengths=lengths,
                chunk=chunk)
            if new_c is not None and ca is not None:
                new_c["attn"] = ca
        x = x + shard_act(sub, seq_sharded)

        if "cross" in p:
            hx = rmsnorm(x, p["ln_x"], cfg.rms_eps)
            sub, cx = attention_sublayer(
                p["cross"], hx, ctx, cfg, "X",
                cache=c.get("cross") if c else None, memory=memory, chunk=chunk)
            if new_c is not None and cx is not None:
                new_c["cross"] = cx
            x = x + shard_act(sub, seq_sharded)

        if "moe" in p:
            h2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
            mo, aux = moe_sublayer(p["moe"], h2, cfg, ctx)
            x = x + shard_act(mo, seq_sharded)
        elif "mlp" in p:
            h2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
            x = x + shard_act(dense_mlp(p["mlp"], h2, cfg.act), seq_sharded)
        return x, new_c, aux

    def _run_groups(self, groups_params, x, cache, *, ctx: RunCtx, layer_groups,
                    positions=None, memory=None, page_table=None, lengths=None,
                    kinds_override: Optional[str] = None, chunk=None):
        """Scan each layer group. Returns (x, new_cache, aux_sum)."""
        aux_total = jnp.zeros((), jnp.float32)
        new_groups_cache: List[Any] = []
        for gi, g in enumerate(layer_groups):
            gp = groups_params[gi]["layers"]
            gc = cache["groups"][gi] if cache is not None else None
            pattern = kinds_override or g.pattern

            if not ctx.scan_layers:
                # unrolled python loop (roofline cost lowering: XLA counts
                # loop bodies once, so the cost model must not use scan)
                new_gc = gc
                for r in range(g.repeats):
                    p_sl = jax.tree.map(lambda x: x[r], gp)
                    c_sl = (jax.tree.map(lambda x: x[r], new_gc)
                            if new_gc is not None else None)
                    for pos, kind in enumerate(pattern):
                        cpos = c_sl[pos] if c_sl is not None else None

                        def run_layer(pp, xx, cc, kind=kind):
                            return self._apply_layer(
                                pp, xx, cc, kind=kind, ctx=ctx,
                                positions=positions, memory=memory,
                                page_table=page_table, lengths=lengths,
                                chunk=chunk)

                        if ctx.remat:
                            run_layer = jax.checkpoint(run_layer)
                        x, cnew, aux = run_layer(p_sl[pos], x, cpos)
                        x = shard_act(x, ("batch", "seq", None))
                        aux_total = aux_total + aux
                        if new_gc is not None and cnew is not None:
                            new_gc = [
                                (jax.tree.map(lambda full, new: full.at[r].set(new),
                                              new_gc[pp], cnew) if pp == pos else new_gc[pp])
                                for pp in range(len(pattern))
                            ]
                new_groups_cache.append(new_gc)
                continue

            def body(carry, xs, pattern=pattern):
                xcur = carry
                p_sl, c_sl = xs
                auxes = jnp.zeros((), jnp.float32)
                new_cs = []
                for pos, kind in enumerate(pattern):
                    cpos = c_sl[pos] if c_sl is not None else None
                    xcur, cnew, aux = self._apply_layer(
                        p_sl[pos], xcur, cpos, kind=kind, ctx=ctx,
                        positions=positions, memory=memory,
                        page_table=page_table, lengths=lengths, chunk=chunk)
                    # residual stream seq-sharded between layers under the
                    # sequence-parallel rules (no-op otherwise)
                    xcur = shard_act(xcur, ("batch", "seq", None))
                    new_cs.append(cnew)
                    auxes = auxes + aux
                return xcur, (new_cs, auxes)

            if ctx.remat:
                body = jax.checkpoint(body)
            xs = (gp, gc)
            x, (stacked_cache, auxes) = jax.lax.scan(body, x, xs)
            new_groups_cache.append(stacked_cache)
            aux_total = aux_total + jnp.sum(auxes)
        new_cache = {"groups": new_groups_cache} if cache is not None else None
        return x, new_cache, aux_total

    # ------------------------------------------------------------------ embed
    def _embed(self, params, batch, ctx: RunCtx):
        """Returns (x (B,S,d), text_offset) — text_offset = #prefix positions
        (vision patches) preceding the first text token."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"]["w"][tokens]
        if cfg.scale_embedding:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        offset = 0
        if cfg.vision is not None and "patches" in batch:
            proj = (jnp.einsum("bpk,kd->bpd", batch["patches"].astype(x.dtype),
                               params["vision_proj"]["w"].astype(x.dtype))
                    + params["vision_proj"]["b"].astype(x.dtype))
            x = jnp.concatenate([proj, x], axis=1)
            offset = proj.shape[1]
        return shard_act(x, ("batch", "seq", None)), offset

    def _head(self, params, x):
        cfg = self.cfg
        w = params["embed"]["w"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return shard_act(logits, ("batch", "seq", "vocab"))

    def _encode(self, params, frames, ctx: RunCtx):
        """Encoder for encdec models. frames: (B, M, d) stub frontend output."""
        enc = params["encoder"]
        x, _, _ = self._run_groups(
            enc["groups"], frames, None, ctx=ctx,
            layer_groups=(LayerGroup("E", self.cfg.encoder.n_layers),),
            positions=jnp.arange(frames.shape[1]))
        return rmsnorm(x, enc["final_norm"]["w"], self.cfg.rms_eps)

    # ------------------------------------------------------------------ api
    def forward(self, params, batch, ctx: RunCtx):
        """Teacher-forced full-sequence logits. Returns (logits, aux)."""
        cfg = self.cfg
        x, _ = self._embed(params, batch, ctx)
        memory = None
        if cfg.encoder is not None:
            memory = self._encode(params, batch["frames"].astype(x.dtype), ctx)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._run_groups(
            params["groups"], x, None, ctx=ctx, layer_groups=cfg.layer_groups,
            positions=positions, memory=memory)
        x = rmsnorm(x, params["final_norm"]["w"], cfg.rms_eps)
        return self._head(params, x), aux

    def loss(self, params, batch, ctx: RunCtx, aux_weight: float = 0.01,
             xent_chunk: int = 0):
        """``xent_chunk`` > 0 enables sequence-chunked cross-entropy: the
        (B, S, vocab) f32 logits never materialize at once — the head matmul
        + logsumexp run per seq-chunk under remat. Cuts the train-step temp
        memory by the vocab-logits term (the dominant one for 150k-256k
        vocabs); a beyond-paper memory optimization (EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels_safe = jnp.maximum(labels, 0)

        if xent_chunk <= 0:
            logits, aux = self.forward(params, batch, ctx)
            if logits.shape[1] != labels.shape[1]:       # vlm: drop patch positions
                logits = logits[:, logits.shape[1] - labels.shape[1]:]
            logits = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0] - logz
            xent = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            total = xent + aux_weight * aux
            return total, {"xent": xent, "aux": aux}

        # chunked path: trunk features once, head+xent per sequence chunk
        x, offset = self._embed(params, batch, ctx)
        memory = None
        if cfg.encoder is not None:
            memory = self._encode(params, batch["frames"].astype(x.dtype), ctx)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._run_groups(
            params["groups"], x, None, ctx=ctx, layer_groups=cfg.layer_groups,
            positions=positions, memory=memory)
        x = rmsnorm(x, params["final_norm"]["w"], cfg.rms_eps)
        x = x[:, x.shape[1] - labels.shape[1]:]          # drop patch positions
        B, S, _ = x.shape
        C = xent_chunk
        pad = (-S) % C
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels_safe = jnp.pad(labels_safe, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nc = x.shape[1] // C
        xc = x.reshape(B, nc, C, -1).transpose(1, 0, 2, 3)
        lc = labels_safe.reshape(B, nc, C).transpose(1, 0, 2)
        mc = mask.reshape(B, nc, C).transpose(1, 0, 2)

        def chunk_ll(args):
            xi, li, mi = args
            logits = self._head(params, xi).astype(jnp.float32)   # (B, C, V)
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0] - logz
            return jnp.sum(ll * mi)

        lls = jax.lax.map(jax.checkpoint(chunk_ll), (xc, lc, mc))
        xent = -jnp.sum(lls) / jnp.maximum(jnp.sum(mask), 1.0)
        total = xent + aux_weight * aux
        return total, {"xent": xent, "aux": aux}

    def prefill(self, params, batch, cache, ctx: RunCtx, last_pos=None):
        """Full-sequence pass that also fills the cache. ``last_pos`` (B,)
        selects the logits position (true prompt end when the engine pads to a
        bucket); defaults to the final position.
        Returns (last_logits (B, vocab), cache)."""
        cfg = self.cfg
        ctx = ctx.with_mode("prefill")
        x, offset = self._embed(params, batch, ctx)
        memory = None
        if cfg.encoder is not None:
            memory = self._encode(params, batch["frames"].astype(x.dtype), ctx)
        positions = jnp.arange(x.shape[1])
        x, new_cache, _ = self._run_groups(
            params["groups"], x, cache, ctx=ctx, layer_groups=cfg.layer_groups,
            positions=positions, memory=memory)
        x = rmsnorm(x, params["final_norm"]["w"], cfg.rms_eps)
        if last_pos is None:
            last = x[:, -1:]
        else:
            last = jnp.take_along_axis(x, (last_pos + offset)[:, None, None], axis=1)
        logits = self._head(params, last)
        return logits[:, 0], new_cache

    def decode_chunk(self, params, tokens, cache, starts, nvalid, slots, first,
                     ctx: RunCtx, page_table, frames=None, patches=None,
                     all_logits: bool = False):
        """Unified serving iteration over a paged cache (DESIGN.md §2): each
        batch row feeds a chunk of up to C tokens of one sequence — C == 1 is
        decode, C > 1 is a prefill chunk. KV goes straight into the paged
        pool; there is no dense intermediate cache and no scatter copy.

        tokens (B, C); starts (B,) absolute position of each row's first
        token (pre-vision-offset); nvalid (B,) live tokens per row (0 =
        inactive row); slots (B,) engine slot per row (must be distinct);
        first (B,) True on a sequence's first chunk (resets SSM/conv state);
        page_table (B, max_pages); frames (B, M, d) raw encoder frames for
        encdec prefill chunks (encoded here, cross-KV persisted per slot);
        patches (B, n_patches, d_patch) for VLM chunk calls —
        the patch prefix is embedded into rows with starts == 0 and its KV
        occupies kv positions [0, n_patches).

        Returns (logits (B, vocab) at each row's last valid position,
        new_cache). With ``all_logits`` the head runs on every token
        position instead — (B, C, vocab), patch-prefix positions dropped —
        which is the verify step of speculative decoding (DESIGN.md §3):
        position j scores the token fed at index j+1.
        """
        cfg = self.cfg
        if cfg.vision is not None and any("M" in g.pattern for g in cfg.layer_groups):
            # SSM chunk masking is indexed by nvalid over the token axis and
            # would treat a patch prefix as live tokens — refuse loudly
            # rather than corrupt state (no current config hits this).
            raise NotImplementedError(
                "chunk mode: vision patch prefix + SSM layers is unsupported")
        ctx = ctx.with_mode("chunk")
        B, C = tokens.shape
        x = params["embed"]["w"][tokens]
        if cfg.scale_embedding:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        offset = cfg.vision.n_patches if cfg.vision is not None else 0
        positions = offset + starts[:, None] + jnp.arange(C)[None, :]
        valid = jnp.arange(C)[None, :] < nvalid[:, None]
        n_prefix = 0
        if cfg.vision is not None and patches is not None:
            proj = (jnp.einsum("bpk,kd->bpd", patches.astype(x.dtype),
                               params["vision_proj"]["w"].astype(x.dtype))
                    + params["vision_proj"]["b"].astype(x.dtype))
            n_prefix = proj.shape[1]
            x = jnp.concatenate([proj, x], axis=1)
            pre_pos = jnp.broadcast_to(jnp.arange(n_prefix)[None, :], (B, n_prefix))
            pre_valid = jnp.broadcast_to(((starts == 0) & (nvalid > 0))[:, None],
                                         (B, n_prefix))
            positions = jnp.concatenate([pre_pos, positions], axis=1)
            valid = jnp.concatenate([pre_valid, valid], axis=1)
        lengths = offset + starts + nvalid
        memory = None
        if cfg.encoder is not None and frames is not None:
            memory = self._encode(params, frames.astype(x.dtype), ctx)
        pack = {"slots": slots, "nvalid": nvalid, "first": first, "valid": valid,
                "prefix": n_prefix > 0}
        x, new_cache, _ = self._run_groups(
            params["groups"], x, cache, ctx=ctx, layer_groups=cfg.layer_groups,
            positions=positions, memory=memory, page_table=page_table,
            lengths=lengths, chunk=pack)
        x = rmsnorm(x, params["final_norm"]["w"], cfg.rms_eps)
        if all_logits:
            return self._head(params, x[:, n_prefix:]), new_cache
        last = n_prefix + jnp.maximum(nvalid, 1) - 1
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
        logits = self._head(params, x_last)
        return logits[:, 0], new_cache

    def decode_step(self, params, tokens, cache, positions, ctx: RunCtx,
                    page_table=None, lengths=None):
        """tokens (B,1); positions (B,) absolute position of the new token.
        Returns (logits (B, vocab), new_cache)."""
        cfg = self.cfg
        ctx = ctx.with_mode("decode")
        x = params["embed"]["w"][tokens]
        if cfg.scale_embedding:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if lengths is None:
            lengths = positions + 1
        x, new_cache, _ = self._run_groups(
            params["groups"], x, cache, ctx=ctx, layer_groups=cfg.layer_groups,
            positions=positions, page_table=page_table, lengths=lengths)
        x = rmsnorm(x, params["final_norm"]["w"], cfg.rms_eps)
        logits = self._head(params, x)
        return logits[:, 0], new_cache

    # ------------------------------------------------------------------ cache
    def init_cache(self, B: int, max_seq: int, dtype=jnp.float32, *,
                   kind: str = "dense", page_size: int = 16,
                   num_pages: int = 0, memory_len: int = 0):
        """Build the cache pytree (call under jax.eval_shape for the dry-run).

        kind="dense": per-layer ring buffers (window layers get W=window).
        kind="paged": per-layer physical page pools (engine supplies
                      page_table/lengths at decode time).
        """
        cfg = self.cfg
        Hkv, hd = cfg.n_kv_heads, cfg.head_dim
        groups_cache = []
        for g in cfg.layer_groups:
            R = g.repeats
            per_pos = []
            for pos, k in enumerate(g.pattern):
                c: Dict[str, Any] = {}
                if k == "M":
                    ssm = cfg.ssm
                    c["ssm"] = {
                        "state": jnp.zeros((R, B, cfg.ssm_heads, ssm.head_dim, ssm.d_state), jnp.float32),
                        "conv": jnp.zeros((R, B, cfg.d_inner + 2 * ssm.n_groups * ssm.d_state,
                                           ssm.d_conv - 1), dtype),
                    }
                else:
                    W = min(max_seq, cfg.sliding_window) if (k == "L" and cfg.sliding_window) else max_seq
                    if kind == "paged":
                        c["attn"] = {
                            "kp": jnp.zeros((R, num_pages, page_size, Hkv, hd), dtype),
                            "vp": jnp.zeros((R, num_pages, page_size, Hkv, hd), dtype),
                        }
                    else:
                        c["attn"] = {
                            "k": jnp.zeros((R, B, W, Hkv, hd), dtype),
                            "v": jnp.zeros((R, B, W, Hkv, hd), dtype),
                            "slot_pos": jnp.full((R, B, W), -1, jnp.int32),
                        }
                if cfg.family == "encdec":
                    M = memory_len or cfg.encoder.cross_attn_memory
                    c["cross"] = {
                        "ck": jnp.zeros((R, B, M, Hkv, hd), dtype),
                        "cv": jnp.zeros((R, B, M, Hkv, hd), dtype),
                    }
                per_pos.append(c)
            groups_cache.append(per_pos)
        return {"groups": groups_cache}


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
