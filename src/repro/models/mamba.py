"""Mamba2 layer via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm (train/prefill): within-chunk quadratic ("attention-like")
term + cross-chunk state recurrence (lax.scan over chunks). Decode is an O(1)
state update — this is why the SSM archs run the long_500k cell.

Cache per layer: {"state": (B, H, P, N) f32, "conv": (B, conv_dim, d_conv-1)}.
All decays are exp(<=0) — numerically bounded by construction.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import RunCtx, rmsnorm


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_in, H = cfg.d_inner, cfg.ssm_heads
    GN = cfg.ssm.n_groups * cfg.ssm.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * GN]
    dt = zxbcdt[..., 2 * d_in + 2 * GN :]
    return z, xbc, dt


def _conv_full(xbc, conv_w, conv_b):
    """Causal depthwise conv over sequence. xbc (B,S,C); conv_w (C, K)."""
    B, S, C = xbc.shape
    K = conv_w.shape[-1]
    lhs = xbc.transpose(0, 2, 1)                          # (B, C, S)
    rhs = conv_w[:, None, :]                              # (C, 1, K)
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32), rhs.astype(jnp.float32),
        window_strides=(1,), padding=[(K - 1, 0)], feature_group_count=C,
    )
    out = out.transpose(0, 2, 1) + conv_b[None, None, :]
    return jax.nn.silu(out).astype(xbc.dtype)


def _conv_step(xbc_new, conv_state, conv_w, conv_b):
    """xbc_new (B,1,C); conv_state (B,C,K-1). Returns (out (B,1,C), new_state)."""
    window = jnp.concatenate([conv_state, xbc_new.transpose(0, 2, 1)], axis=-1)  # (B,C,K)
    out = jnp.sum(window.astype(jnp.float32) * conv_w[None].astype(jnp.float32), axis=-1)
    out = jax.nn.silu(out + conv_b[None]).astype(xbc_new.dtype)
    return out[:, None, :], window[..., 1:]


def _conv_carry(xbc, conv_state, conv_w, conv_b):
    """Causal conv continuing from a carried tail. xbc (B,S,C); conv_state
    (B,C,K-1) holds the K-1 inputs preceding the chunk (zeros at sequence
    start). Returns (out (B,S,C), window (B, K-1+S, C)) — the window is
    reused by the caller to slice the next carry at a ragged boundary."""
    B, S, C = xbc.shape
    K = conv_w.shape[-1]
    window = jnp.concatenate([conv_state.transpose(0, 2, 1).astype(xbc.dtype), xbc],
                             axis=1)                       # (B, K-1+S, C)
    lhs = window.transpose(0, 2, 1)                        # (B, C, K-1+S)
    rhs = conv_w[:, None, :]                               # (C, 1, K)
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32), rhs.astype(jnp.float32),
        window_strides=(1,), padding="VALID", feature_group_count=C,
    )
    out = out.transpose(0, 2, 1) + conv_b[None, None, :]
    return jax.nn.silu(out).astype(xbc.dtype), window


def ssd_chunked(x, dt, A, B_, C, chunk: int, init_state=None):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative; B_/C (B,L,H,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    Bb, L, H, Pd = x.shape
    N = B_.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc, Q = Lp // chunk, chunk

    f32 = jnp.float32
    xc = x.reshape(Bb, nc, Q, H, Pd).astype(f32)
    dtc = dt.reshape(Bb, nc, Q, H).astype(f32)
    Bc = B_.reshape(Bb, nc, Q, H, N).astype(f32)
    Cc = C.reshape(Bb, nc, Q, H, N).astype(f32)

    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                          # inclusive

    # within-chunk (quadratic) term
    CB = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)         # (B,nc,H,Q,Q)
    decay = jnp.exp(cum.transpose(0, 1, 3, 2)[..., :, None] - cum.transpose(0, 1, 3, 2)[..., None, :])
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, None]
    M = jnp.where(causal, CB * decay, 0.0) * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xc)

    # per-chunk input states and cross-chunk recurrence
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", decay_out * dtc, Bc, xc)  # (B,nc,H,P,N)
    T_c = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    s0 = jnp.zeros((Bb, H, Pd, N), f32) if init_state is None else init_state.astype(f32)

    def chunk_step(s, inputs):
        t_c, s_c = inputs                                 # (B,H), (B,H,P,N)
        s_new = s * t_c[..., None, None] + s_c
        return s_new, s                                   # emit state BEFORE this chunk

    T_s = T_c.transpose(1, 0, 2)                          # (nc,B,H)
    S_s = S_c.transpose(1, 0, 2, 3, 4)                    # (nc,B,H,P,N)
    final_state, prev_states = jax.lax.scan(chunk_step, s0, (T_s, S_s))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,N)

    decay_in = jnp.exp(cum)                               # (B,nc,Q,H)
    y_off = jnp.einsum("bcihn,bchpn->bcihp", Cc, prev_states) * decay_in[..., None]

    y = (y_diag + y_off).reshape(Bb, Lp, H, Pd)[:, :L]
    return y, final_state


def _ssm_decode_update(xbc_c, dt1, A, p, state, cfg: ModelConfig):
    """One-token SSD state update. xbc_c (B,1,conv_dim) post-conv; dt1 (B,H);
    state (B,H,P,N) f32. Returns (y (B,1,d_inner) f32, new_state f32)."""
    d_in, H, Pd = cfg.d_inner, cfg.ssm_heads, cfg.ssm.head_dim
    G, N = cfg.ssm.n_groups, cfg.ssm.d_state
    B = xbc_c.shape[0]
    xh = xbc_c[:, 0, :d_in].reshape(B, H, Pd).astype(jnp.float32)
    Bm = xbc_c[:, 0, d_in : d_in + G * N].reshape(B, G, N).astype(jnp.float32)
    Cm = xbc_c[:, 0, d_in + G * N :].reshape(B, G, N).astype(jnp.float32)
    Bm = jnp.repeat(Bm, H // G, axis=1)                   # (B,H,N)
    Cm = jnp.repeat(Cm, H // G, axis=1)
    dA = jnp.exp(dt1 * A[None, :])                        # (B,H)
    state = state * dA[..., None, None] + jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bm, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Cm, state)            # (B,H,P)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    return y.reshape(B, 1, d_in), state


def mamba_sublayer(
    p: Dict[str, Any],
    h,                      # normed (B, S, d)
    cfg: ModelConfig,
    ctx: RunCtx,
    cache: Optional[Dict[str, Any]] = None,
    chunk: Optional[Dict[str, Any]] = None,
) -> Tuple[Any, Optional[Dict[str, Any]]]:
    ssm = cfg.ssm
    d_in, H, Pd = cfg.d_inner, cfg.ssm_heads, ssm.head_dim
    G, N, K = ssm.n_groups, ssm.d_state, ssm.d_conv
    B, S, _ = h.shape

    zxbcdt = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if ctx.mode == "chunk":
        # serving chunk over the slot-pooled cache: rows map to engine slots,
        # first chunks start from zero state, ragged tails are masked via dt
        # (dt == 0 => exp(dt*A) == 1 and zero input: the state is untouched).
        slots, nvalid, first = chunk["slots"], chunk["nvalid"], chunk["first"]
        row_valid = nvalid > 0
        s_orig = cache["state"][slots]
        c_orig = cache["conv"][slots]
        s0 = jnp.where(first[:, None, None, None], 0.0, s_orig.astype(jnp.float32))
        c0 = jnp.where(first[:, None, None], jnp.zeros_like(c_orig), c_orig)
        if S == 1:                                        # decode: O(1) update
            xbc_c, conv_new = _conv_step(xbc, c0, p["conv_w"], p["conv_b"])
            y, state_new = _ssm_decode_update(xbc_c, dt[:, 0], A, p, s0, cfg)
        else:
            xbc_c, window = _conv_carry(xbc, c0, p["conv_w"], p["conv_b"])
            xh = xbc_c[..., :d_in].reshape(B, S, H, Pd)
            Bm = xbc_c[..., d_in : d_in + G * N].reshape(B, S, G, N)
            Cm = xbc_c[..., d_in + G * N :].reshape(B, S, G, N)
            Bm = jnp.repeat(Bm, H // G, axis=2)
            Cm = jnp.repeat(Cm, H // G, axis=2)
            dtm = jnp.where(jnp.arange(S)[None, :, None] < nvalid[:, None, None],
                            dt, 0.0)
            y, state_new = ssd_chunked(xh, dtm, A, Bm, Cm, ssm.chunk_size,
                                       init_state=s0)
            y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
            y = y.reshape(B, S, d_in)
            # next carry: the K-1 inputs preceding each row's ragged end
            idx = nvalid[:, None] + jnp.arange(K - 1)[None]
            conv_new = jnp.take_along_axis(window, idx[..., None], axis=1
                                           ).transpose(0, 2, 1)
        new_cache = {
            "state": cache["state"].at[slots].set(
                jnp.where(row_valid[:, None, None, None],
                          state_new.astype(cache["state"].dtype), s_orig)),
            "conv": cache["conv"].at[slots].set(
                jnp.where(row_valid[:, None, None],
                          conv_new.astype(cache["conv"].dtype), c_orig)),
        }
    elif ctx.mode == "decode":
        xbc_c, new_conv = _conv_step(xbc, cache["conv"], p["conv_w"], p["conv_b"])
        y, state = _ssm_decode_update(xbc_c, dt[:, 0], A, p,
                                      cache["state"].astype(jnp.float32), cfg)
        new_cache = {"state": state, "conv": new_conv}
    else:
        xbc_c = _conv_full(xbc, p["conv_w"], p["conv_b"])
        xh = xbc_c[..., :d_in].reshape(B, S, H, Pd)
        Bm = xbc_c[..., d_in : d_in + G * N].reshape(B, S, G, N)
        Cm = xbc_c[..., d_in + G * N :].reshape(B, S, G, N)
        Bm = jnp.repeat(Bm, H // G, axis=2)
        Cm = jnp.repeat(Cm, H // G, axis=2)
        init_state = cache["state"] if (cache is not None and ctx.mode == "prefill") else None
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk_size, init_state=init_state)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, S, d_in)
        new_cache = cache
        if cache is not None:                             # prefill: hand off state
            tail = xbc[:, -(K - 1):, :]
            if S < K - 1:
                tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
            new_cache = {"state": final_state, "conv": tail.transpose(0, 2, 1)}

    # gated RMSNorm + out projection
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype), p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_cache
