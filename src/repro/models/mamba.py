"""Mamba2 layer via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm (train/prefill): within-chunk quadratic ("attention-like")
term + cross-chunk state recurrence (lax.scan over chunks). Decode is an O(1)
state update — this is why the SSM archs run the long_500k cell.

Cache per layer: {"state": (B, H, P, N) f32, "conv": (B, conv_dim, d_conv-1)}.
All decays are exp(<=0) — numerically bounded by construction.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import RunCtx, rmsnorm


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_in, H = cfg.d_inner, cfg.ssm_heads
    GN = cfg.ssm.n_groups * cfg.ssm.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * GN]
    dt = zxbcdt[..., 2 * d_in + 2 * GN :]
    return z, xbc, dt


def _conv_full(xbc, conv_w, conv_b):
    """Causal depthwise conv over sequence. xbc (B,S,C); conv_w (C, K)."""
    B, S, C = xbc.shape
    K = conv_w.shape[-1]
    lhs = xbc.transpose(0, 2, 1)                          # (B, C, S)
    rhs = conv_w[:, None, :]                              # (C, 1, K)
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32), rhs.astype(jnp.float32),
        window_strides=(1,), padding=[(K - 1, 0)], feature_group_count=C,
    )
    out = out.transpose(0, 2, 1) + conv_b[None, None, :]
    return jax.nn.silu(out).astype(xbc.dtype)


def _conv_step(xbc_new, conv_state, conv_w, conv_b):
    """xbc_new (B,1,C); conv_state (B,C,K-1). Returns (out (B,1,C), new_state)."""
    window = jnp.concatenate([conv_state, xbc_new.transpose(0, 2, 1)], axis=-1)  # (B,C,K)
    out = jnp.sum(window.astype(jnp.float32) * conv_w[None].astype(jnp.float32), axis=-1)
    out = jax.nn.silu(out + conv_b[None]).astype(xbc_new.dtype)
    return out[:, None, :], window[..., 1:]


def ssd_chunked(x, dt, A, B_, C, chunk: int, init_state=None):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative; B_/C (B,L,H,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    Bb, L, H, Pd = x.shape
    N = B_.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc, Q = Lp // chunk, chunk

    f32 = jnp.float32
    xc = x.reshape(Bb, nc, Q, H, Pd).astype(f32)
    dtc = dt.reshape(Bb, nc, Q, H).astype(f32)
    Bc = B_.reshape(Bb, nc, Q, H, N).astype(f32)
    Cc = C.reshape(Bb, nc, Q, H, N).astype(f32)

    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                          # inclusive

    # within-chunk (quadratic) term
    CB = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)         # (B,nc,H,Q,Q)
    decay = jnp.exp(cum.transpose(0, 1, 3, 2)[..., :, None] - cum.transpose(0, 1, 3, 2)[..., None, :])
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, None]
    M = jnp.where(causal, CB * decay, 0.0) * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xc)

    # per-chunk input states and cross-chunk recurrence
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", decay_out * dtc, Bc, xc)  # (B,nc,H,P,N)
    T_c = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    s0 = jnp.zeros((Bb, H, Pd, N), f32) if init_state is None else init_state.astype(f32)

    def chunk_step(s, inputs):
        t_c, s_c = inputs                                 # (B,H), (B,H,P,N)
        s_new = s * t_c[..., None, None] + s_c
        return s_new, s                                   # emit state BEFORE this chunk

    T_s = T_c.transpose(1, 0, 2)                          # (nc,B,H)
    S_s = S_c.transpose(1, 0, 2, 3, 4)                    # (nc,B,H,P,N)
    final_state, prev_states = jax.lax.scan(chunk_step, s0, (T_s, S_s))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,N)

    decay_in = jnp.exp(cum)                               # (B,nc,Q,H)
    y_off = jnp.einsum("bcihn,bchpn->bcihp", Cc, prev_states) * decay_in[..., None]

    y = (y_diag + y_off).reshape(Bb, Lp, H, Pd)[:, :L]
    return y, final_state


def mamba_sublayer(
    p: Dict[str, Any],
    h,                      # normed (B, S, d)
    cfg: ModelConfig,
    ctx: RunCtx,
    cache: Optional[Dict[str, Any]] = None,
) -> Tuple[Any, Optional[Dict[str, Any]]]:
    ssm = cfg.ssm
    d_in, H, Pd = cfg.d_inner, cfg.ssm_heads, ssm.head_dim
    G, N, K = ssm.n_groups, ssm.d_state, ssm.d_conv
    B, S, _ = h.shape

    zxbcdt = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if ctx.mode == "decode":
        xbc_c, new_conv = _conv_step(xbc, cache["conv"], p["conv_w"], p["conv_b"])
        xh = xbc_c[..., :d_in].reshape(B, H, Pd).astype(jnp.float32)
        Bm = xbc_c[..., d_in : d_in + G * N].reshape(B, G, N).astype(jnp.float32)
        Cm = xbc_c[..., d_in + G * N :].reshape(B, G, N).astype(jnp.float32)
        Bm = jnp.repeat(Bm, H // G, axis=1)               # (B,H,N)
        Cm = jnp.repeat(Cm, H // G, axis=1)
        dt1 = dt[:, 0]                                    # (B,H)
        dA = jnp.exp(dt1 * A[None, :])                    # (B,H)
        state = cache["state"].astype(jnp.float32)
        state = state * dA[..., None, None] + jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bm, xh)
        y = jnp.einsum("bhn,bhpn->bhp", Cm, state)        # (B,H,P)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(B, 1, d_in)
        new_cache = {"state": state, "conv": new_conv}
    else:
        xbc_c = _conv_full(xbc, p["conv_w"], p["conv_b"])
        xh = xbc_c[..., :d_in].reshape(B, S, H, Pd)
        Bm = xbc_c[..., d_in : d_in + G * N].reshape(B, S, G, N)
        Cm = xbc_c[..., d_in + G * N :].reshape(B, S, G, N)
        Bm = jnp.repeat(Bm, H // G, axis=2)
        Cm = jnp.repeat(Cm, H // G, axis=2)
        init_state = cache["state"] if (cache is not None and ctx.mode == "prefill") else None
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk_size, init_state=init_state)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, S, d_in)
        new_cache = cache
        if cache is not None:                             # prefill: hand off state
            tail = xbc[:, -(K - 1):, :]
            if S < K - 1:
                tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
            new_cache = {"state": final_state, "conv": tail.transpose(0, 2, 1)}

    # gated RMSNorm + out projection
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype), p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_cache
