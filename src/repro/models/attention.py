"""GQA attention sublayer: train / prefill / chunk (paged serving) / decode
(dense ring-buffer cache or paged cache) / cross-attention. One code path per
mode, shared projections.

Cache formats (per layer, unstacked — the scan adds the leading layers dim):
  dense: {"k": (B, W, Hkv, hd), "v": ..., "slot_pos": (B, W) int32}
         W = min(max_seq, window) — a ring buffer; slot_pos holds the absolute
         position stored in each slot (-1 = empty). Full attention is W=max_seq
         (slot == position) through the same code.
  paged: {"kp": (P, ps, Hkv, hd), "vp": ...} + engine-level page_table/lengths.
  cross: {"ck": (B, M, Hkv, hd), "cv": ...} built once at prefill.

Mode "chunk" is the serving engine's unified iteration (DESIGN.md §2): each
batch row carries a chunk of S tokens of one sequence (S == 1 is decode); KV
is written straight into the paged pool (no dense intermediate) and queries
attend causally over the pool, which already contains the chunk itself.
``chunk`` carries {"slots", "nvalid", "first", "valid"} — the engine-slot id,
the per-row count of live tokens, whether this is the row's first chunk, and
the per-position validity mask (invalid positions write to null page 0).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (chunked_prefill_attention,
                                           paged_attention)
from repro.models.common import RunCtx, rope, shard_act


def _project_qkv(p, h, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", h, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", h, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return q, k, v


def _out_proj(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _decode_dense_attn(q, cache, positions, *, window: int, softcap: float, scale: float):
    """q: (B,1,H,hd); ring-buffer cache. Plain einsum (q len 1 needs no tiling);
    shards under GSPMD, incl. seq-sharded caches (softmax combine collectives)."""
    k, v, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    B, W, Hkv, hd = k.shape
    H = q.shape[2]
    G = H // Hkv
    q5 = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqngd,bsnd->bnqgs", q5, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = positions[:, None]                       # (B,1) current absolute position
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[:, None, None, None, :], s, -1e30)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnqgs,bsnd->bqngd", p_attn.astype(jnp.float32), v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def _write_ring(cache, k, v, positions):
    """Scatter new kv at positions into the ring buffer. decode: k (B,1,Hkv,hd),
    positions (B,). prefill: k (B,S,...), positions (S,) shared across batch."""
    W = cache["k"].shape[1]
    if k.shape[1] == 1 and positions.ndim == 1 and positions.shape[0] == k.shape[0]:
        slots = positions % W                       # (B,)
        b_idx = jnp.arange(k.shape[0])
        new_k = cache["k"].at[b_idx, slots].set(k[:, 0])
        new_v = cache["v"].at[b_idx, slots].set(v[:, 0])
        new_sp = cache["slot_pos"].at[b_idx, slots].set(positions)
    else:                                           # prefill: positions (S,)
        S = k.shape[1]
        if S > W:                                   # keep the last W tokens
            k, v, positions = k[:, -W:], v[:, -W:], positions[-W:]
        slots = positions % W
        new_k = cache["k"].at[:, slots].set(k)
        new_v = cache["v"].at[:, slots].set(v)
        new_sp = cache["slot_pos"].at[:, slots].set(positions[None, :])
    return {"k": new_k, "v": new_v, "slot_pos": new_sp}


def _write_paged(cache, k, v, positions, page_table):
    """k (B,1,Hkv,hd); positions (B,) absolute; page_table (B, maxp)."""
    ps = cache["kp"].shape[1]
    b_idx = jnp.arange(k.shape[0])
    logical = positions // ps
    slot = positions % ps
    phys = page_table[b_idx, logical]
    return {
        "kp": cache["kp"].at[phys, slot].set(k[:, 0]),
        "vp": cache["vp"].at[phys, slot].set(v[:, 0]),
    }


def _write_paged_chunk(cache, k, v, positions, page_table, valid):
    """Scatter a whole chunk's KV into the paged pool in one shot.

    k/v (B, S, Hkv, hd); positions (B, S) absolute; valid (B, S). Invalid
    positions are routed to the reserved null page 0 (the allocator never
    hands it out), so one fixed-shape scatter serves ragged chunks."""
    ps = cache["kp"].shape[1]
    B, S = positions.shape
    maxp = page_table.shape[1]
    logical = jnp.clip(positions // ps, 0, maxp - 1)
    phys = jnp.where(valid, jnp.take_along_axis(page_table, logical, axis=1), 0)
    slot = positions % ps
    pf, sf = phys.reshape(-1), slot.reshape(-1)
    kf = k.reshape(B * S, *k.shape[2:]).astype(cache["kp"].dtype)
    vf = v.reshape(B * S, *v.shape[2:]).astype(cache["vp"].dtype)
    return {
        "kp": cache["kp"].at[pf, sf].set(kf),
        "vp": cache["vp"].at[pf, sf].set(vf),
    }


def attention_sublayer(
    p: Dict[str, Any],
    h,                       # normed input (B, S, d)
    ctx: RunCtx,
    cfg: ModelConfig,
    kind: str,               # 'A' | 'L' | 'G' | 'X' (cross) | 'E' (encoder, bidirectional)
    cache: Optional[Dict[str, Any]] = None,
    positions=None,          # decode: (B,) abs position of the new token;
                             # prefill: (S,); chunk: (B, S) absolute
    memory=None,             # cross: encoder output (B, M, d)
    page_table=None,
    lengths=None,
    chunk=None,              # chunk mode: {"slots", "nvalid", "first", "valid"}
):
    """Returns (attn_out (B,S,d), new_cache)."""
    window = cfg.sliding_window if kind == "L" else 0
    softcap = cfg.attn_softcap
    scale = cfg.head_dim ** -0.5
    B, S, _ = h.shape

    # ---------------- cross attention ----------------
    if kind == "X":
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        if ctx.mode == "chunk":
            # slot-pooled cross cache: rows map to engine slots. With encoder
            # memory supplied (prefill chunks) recompute ck/cv and persist
            # them at the row's slot; without (decode sweep) read the slot.
            slots, row_valid = chunk["slots"], chunk["nvalid"] > 0
            if memory is not None:
                ck = jnp.einsum("bmd,dnk->bmnk", memory, p["wk"])
                cv = jnp.einsum("bmd,dnk->bmnk", memory, p["wv"])
                new_cache = {
                    "ck": cache["ck"].at[slots].set(
                        jnp.where(row_valid[:, None, None, None],
                                  ck.astype(cache["ck"].dtype), cache["ck"][slots])),
                    "cv": cache["cv"].at[slots].set(
                        jnp.where(row_valid[:, None, None, None],
                                  cv.astype(cache["cv"].dtype), cache["cv"][slots])),
                }
            else:
                ck, cv = cache["ck"][slots], cache["cv"][slots]
                new_cache = cache
        elif cache is not None and ctx.mode == "decode":
            ck, cv = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            ck = jnp.einsum("bmd,dnk->bmnk", memory, p["wk"])
            cv = jnp.einsum("bmd,dnk->bmnk", memory, p["wv"])
            new_cache = {"ck": ck, "cv": cv} if cache is not None else None
        o = flash_attention(
            q, ck, cv, causal=False, softcap=softcap, scale=scale,
            backend=ctx.attn_backend, block_q=ctx.block_q, block_kv=ctx.block_kv,
            unroll=ctx.attn_unroll,
        )
        return _out_proj(p, o), new_cache

    q, k, v = _project_qkv(p, h, cfg)

    if ctx.mode == "chunk" and kind != "E":   # encoder runs full-seq below
        # serving chunk: write this chunk's KV straight into the paged pool,
        # then attend causally over the pool (history + the chunk itself).
        # Rows carrying a vision patch prefix have non-affine positions,
        # which the pallas kernel cannot represent — force the xla gather.
        backend = "xla" if chunk.get("prefix") else ctx.attn_backend
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        new_cache = _write_paged_chunk(cache, k, v, positions, page_table,
                                       chunk["valid"])
        o = chunked_prefill_attention(
            q, new_cache["kp"], new_cache["vp"], page_table, lengths, positions,
            scale=scale, softcap=softcap, window=window,
            backend=backend, interpret=ctx.interpret,
        )
        return _out_proj(p, o), new_cache

    if ctx.mode == "decode":
        q = rope(q, positions[:, None], cfg.rope_theta)   # (B,1,...)
        k = rope(k, positions[:, None], cfg.rope_theta)
        if cache is not None and "kp" in cache:           # paged
            new_cache = _write_paged(cache, k, v, positions, page_table)
            o = paged_attention(
                q[:, 0], new_cache["kp"], new_cache["vp"], page_table, lengths,
                scale=scale, softcap=softcap, window=window,
                backend=ctx.attn_backend, interpret=ctx.interpret,
            )[:, None]                                     # (B,1,H,hd)
        else:                                              # dense ring cache
            new_cache = _write_ring(cache, k, v, positions)
            o = _decode_dense_attn(q, new_cache, positions, window=window,
                                   softcap=softcap, scale=scale)
        return _out_proj(p, o), new_cache

    # ---------------- train / prefill / encoder ----------------
    if positions is None:
        positions = jnp.arange(S)
    causal = kind != "E"
    q = rope(q, positions, cfg.rope_theta)
    k_roped = rope(k, positions, cfg.rope_theta)
    # Megatron-SP placement: when sequence parallelism is active, the residual
    # stream stays seq-sharded BETWEEN layers; q/k must be whole-sequence here
    # (logical None on seq => GSPMD inserts the all-gather at the projection
    # and the reduce-scatter after the out-projection).
    q = shard_act(q, ("batch", None, "heads", None))
    k_roped = shard_act(k_roped, ("batch", None, "kv_heads", None))
    o = flash_attention(
        q, k_roped, v, causal=causal, window=window, softcap=softcap, scale=scale,
        backend=ctx.attn_backend, block_q=ctx.block_q, block_kv=ctx.block_kv,
        unroll=ctx.attn_unroll,
    )
    new_cache = cache
    if cache is not None and "k" in cache:                 # prefill: persist kv
        new_cache = _write_ring(cache, k_roped, v, positions)
    return _out_proj(p, o), new_cache
