"""GQA attention sublayer: train / prefill / decode (dense ring-buffer cache or
paged cache) / cross-attention. One code path per mode, shared projections.

Cache formats (per layer, unstacked — the scan adds the leading layers dim):
  dense: {"k": (B, W, Hkv, hd), "v": ..., "slot_pos": (B, W) int32}
         W = min(max_seq, window) — a ring buffer; slot_pos holds the absolute
         position stored in each slot (-1 = empty). Full attention is W=max_seq
         (slot == position) through the same code.
  paged: {"kp": (P, ps, Hkv, hd), "vp": ...} + engine-level page_table/lengths.
  cross: {"ck": (B, M, Hkv, hd), "cv": ...} built once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.models.common import RunCtx, rope, shard_act


def _project_qkv(p, h, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", h, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", h, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return q, k, v


def _out_proj(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _decode_dense_attn(q, cache, positions, *, window: int, softcap: float, scale: float):
    """q: (B,1,H,hd); ring-buffer cache. Plain einsum (q len 1 needs no tiling);
    shards under GSPMD, incl. seq-sharded caches (softmax combine collectives)."""
    k, v, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    B, W, Hkv, hd = k.shape
    H = q.shape[2]
    G = H // Hkv
    q5 = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqngd,bsnd->bnqgs", q5, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = positions[:, None]                       # (B,1) current absolute position
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[:, None, None, None, :], s, -1e30)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnqgs,bsnd->bqngd", p_attn.astype(jnp.float32), v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def _write_ring(cache, k, v, positions):
    """Scatter new kv at positions into the ring buffer. decode: k (B,1,Hkv,hd),
    positions (B,). prefill: k (B,S,...), positions (S,) shared across batch."""
    W = cache["k"].shape[1]
    if k.shape[1] == 1 and positions.ndim == 1 and positions.shape[0] == k.shape[0]:
        slots = positions % W                       # (B,)
        b_idx = jnp.arange(k.shape[0])
        new_k = cache["k"].at[b_idx, slots].set(k[:, 0])
        new_v = cache["v"].at[b_idx, slots].set(v[:, 0])
        new_sp = cache["slot_pos"].at[b_idx, slots].set(positions)
    else:                                           # prefill: positions (S,)
        S = k.shape[1]
        if S > W:                                   # keep the last W tokens
            k, v, positions = k[:, -W:], v[:, -W:], positions[-W:]
        slots = positions % W
        new_k = cache["k"].at[:, slots].set(k)
        new_v = cache["v"].at[:, slots].set(v)
        new_sp = cache["slot_pos"].at[:, slots].set(positions[None, :])
    return {"k": new_k, "v": new_v, "slot_pos": new_sp}


def _write_paged(cache, k, v, positions, page_table):
    """k (B,1,Hkv,hd); positions (B,) absolute; page_table (B, maxp)."""
    ps = cache["kp"].shape[1]
    b_idx = jnp.arange(k.shape[0])
    logical = positions // ps
    slot = positions % ps
    phys = page_table[b_idx, logical]
    return {
        "kp": cache["kp"].at[phys, slot].set(k[:, 0]),
        "vp": cache["vp"].at[phys, slot].set(v[:, 0]),
    }


def attention_sublayer(
    p: Dict[str, Any],
    h,                       # normed input (B, S, d)
    ctx: RunCtx,
    cfg: ModelConfig,
    kind: str,               # 'A' | 'L' | 'G' | 'X' (cross) | 'E' (encoder, bidirectional)
    cache: Optional[Dict[str, Any]] = None,
    positions=None,          # decode: (B,) abs position of the new token; prefill: (S,)
    memory=None,             # cross: encoder output (B, M, d)
    page_table=None,
    lengths=None,
):
    """Returns (attn_out (B,S,d), new_cache)."""
    window = cfg.sliding_window if kind == "L" else 0
    softcap = cfg.attn_softcap
    scale = cfg.head_dim ** -0.5
    B, S, _ = h.shape

    # ---------------- cross attention ----------------
    if kind == "X":
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        if cache is not None and ctx.mode == "decode":
            ck, cv = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            ck = jnp.einsum("bmd,dnk->bmnk", memory, p["wk"])
            cv = jnp.einsum("bmd,dnk->bmnk", memory, p["wv"])
            new_cache = {"ck": ck, "cv": cv} if cache is not None else None
        o = flash_attention(
            q, ck, cv, causal=False, softcap=softcap, scale=scale,
            backend=ctx.attn_backend, block_q=ctx.block_q, block_kv=ctx.block_kv,
            unroll=ctx.attn_unroll,
        )
        return _out_proj(p, o), new_cache

    q, k, v = _project_qkv(p, h, cfg)

    if ctx.mode == "decode":
        q = rope(q, positions[:, None], cfg.rope_theta)   # (B,1,...)
        k = rope(k, positions[:, None], cfg.rope_theta)
        if cache is not None and "kp" in cache:           # paged
            new_cache = _write_paged(cache, k, v, positions, page_table)
            o = paged_attention(
                q[:, 0], new_cache["kp"], new_cache["vp"], page_table, lengths,
                scale=scale, softcap=softcap, window=window,
                backend=ctx.attn_backend, interpret=ctx.interpret,
            )[:, None]                                     # (B,1,H,hd)
        else:                                              # dense ring cache
            new_cache = _write_ring(cache, k, v, positions)
            o = _decode_dense_attn(q, new_cache, positions, window=window,
                                   softcap=softcap, scale=scale)
        return _out_proj(p, o), new_cache

    # ---------------- train / prefill / encoder ----------------
    if positions is None:
        positions = jnp.arange(S)
    causal = kind != "E"
    q = rope(q, positions, cfg.rope_theta)
    k_roped = rope(k, positions, cfg.rope_theta)
    # Megatron-SP placement: when sequence parallelism is active, the residual
    # stream stays seq-sharded BETWEEN layers; q/k must be whole-sequence here
    # (logical None on seq => GSPMD inserts the all-gather at the projection
    # and the reduce-scatter after the out-projection).
    q = shard_act(q, ("batch", None, "heads", None))
    k_roped = shard_act(k_roped, ("batch", None, "kv_heads", None))
    o = flash_attention(
        q, k_roped, v, causal=causal, window=window, softcap=softcap, scale=scale,
        backend=ctx.attn_backend, block_q=ctx.block_q, block_kv=ctx.block_kv,
        unroll=ctx.attn_unroll,
    )
    new_cache = cache
    if cache is not None and "k" in cache:                 # prefill: persist kv
        new_cache = _write_ring(cache, k_roped, v, positions)
    return _out_proj(p, o), new_cache
