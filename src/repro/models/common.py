"""Shared model-runtime context and small layer primitives."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class RunCtx:
    """Threaded through every layer: mode + execution knobs.

    mode: "train" | "prefill" | "decode"
    attn_backend: "auto" | "pallas" | "xla"  (xla = chunked online-softmax jnp;
        it is what the dry-run lowers; pallas is the TPU kernel)
    moe_strategy: "dropless" (exact, serving engine) | "capacity" (local
        capacity buffers) | "tp_shardmap" | "ep_shardmap" (explicit collectives)
    """
    mode: str = "train"
    mesh: Any = None
    attn_backend: str = "xla"
    moe_strategy: str = "capacity"
    remat: bool = False
    block_q: int = 512
    block_kv: int = 1024
    ep_axis: str = "data"
    tp_axis: str = "model"
    interpret: bool = True      # pallas interpret mode (CPU)
    quant: str = "none"         # none | int8 (weight-only serving quant)
    # Cost-model lowering knobs (launch/dryrun): XLA's cost_analysis counts
    # loop bodies ONCE, so the roofline cost lowering unrolls layers and
    # attention tiles (small repeat counts; affine extrapolation).
    scan_layers: bool = True
    attn_unroll: bool = False

    def with_mode(self, mode: str) -> "RunCtx":
        return replace(self, mode=mode)


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (w.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (S,) or (B, S) absolute token positions."""
    B, S, H, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def act_fn(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def dense_mlp(p, x, act_name: str):
    act = act_fn(act_name)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    return jnp.einsum("bsf,fd->bsd", act(g) * h, p["wo"])


def shard_act(x, logical_axes):
    """Apply a with_sharding_constraint from the active logical-axis rules
    (no-op when no rules are installed — CPU unit tests)."""
    from repro.distributed.sharding import constrain
    return constrain(x, logical_axes)
