"""Parameter specification system.

A model's parameters are a nested dict of ``ParamSpec`` leaves — the single
source of truth for shapes, **logical sharding axes**, init, and dtype. From
the spec tree we derive: random init (tests/examples), ShapeDtypeStruct trees
(dry-run, no allocation), PartitionSpec trees (via distributed.sharding
rules), and analytic parameter counts (roofline 6·N·D).

Repeated layers are stacked on a leading "layers" dim (lax.scan over layer
groups), so a group with pattern "LG" x 23 contributes two layer param dicts,
each leaf shaped (23, ...).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "lecun"          # lecun | normal02 | zeros | ones | custom inits below
    tag: str = ""                # "routed_expert" marks MoE routed weights (active-count)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _attn_specs(cfg: ModelConfig, R: int) -> Dict[str, Any]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: Dict[str, Any] = {
        "wq": ParamSpec((R, d, H, hd), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamSpec((R, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((R, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((R, H, hd, d), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((R, H, hd), ("layers", "heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((R, Hkv, hd), ("layers", "kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((R, Hkv, hd), ("layers", "kv_heads", "head_dim"), "zeros")
    return s


def _dense_mlp_specs(cfg: ModelConfig, R: int, d_ff: int) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "wi": ParamSpec((R, d, d_ff), ("layers", "embed", "mlp")),
        "wg": ParamSpec((R, d, d_ff), ("layers", "embed", "mlp")),
        "wo": ParamSpec((R, d_ff, d), ("layers", "mlp", "embed")),
    }


def _moe_specs(cfg: ModelConfig, R: int) -> Dict[str, Any]:
    d, m = cfg.d_model, cfg.moe
    fe = m.d_expert or cfg.d_ff
    s: Dict[str, Any] = {
        "router": ParamSpec((R, d, m.num_experts), ("layers", "embed", None), "normal02"),
        "wg": ParamSpec((R, m.num_experts, d, fe), ("layers", "experts", "embed", "expert_mlp"), tag="routed_expert"),
        "wu": ParamSpec((R, m.num_experts, d, fe), ("layers", "experts", "embed", "expert_mlp"), tag="routed_expert"),
        "wd": ParamSpec((R, m.num_experts, fe, d), ("layers", "experts", "expert_mlp", "embed"), tag="routed_expert"),
    }
    if m.num_shared_experts > 0:
        fs = fe * m.num_shared_experts
        s["shared"] = _dense_mlp_specs(cfg, R, fs)
    return s


def _ssm_specs(cfg: ModelConfig, R: int) -> Dict[str, Any]:
    d, ssm = cfg.d_model, cfg.ssm
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    G, N = ssm.n_groups, ssm.d_state
    d_proj = 2 * d_in + 2 * G * N + H     # z, x, B, C, dt
    conv_dim = d_in + 2 * G * N           # x, B, C go through the causal conv
    return {
        "in_proj": ParamSpec((R, d, d_proj), ("layers", "embed", "ssm_proj")),
        "conv_w": ParamSpec((R, conv_dim, ssm.d_conv), ("layers", "conv_dim", None)),
        "conv_b": ParamSpec((R, conv_dim), ("layers", "conv_dim"), "zeros"),
        "A_log": ParamSpec((R, H), ("layers", "ssm_heads"), "a_log"),
        "D": ParamSpec((R, H), ("layers", "ssm_heads"), "ones"),
        "dt_bias": ParamSpec((R, H), ("layers", "ssm_heads"), "dt_bias"),
        "norm": ParamSpec((R, d_in), ("layers", "ssm_inner"), "ones"),
        "out_proj": ParamSpec((R, d_in, d), ("layers", "ssm_inner", "embed")),
    }


def _layer_specs(cfg: ModelConfig, kind: str, is_moe: bool, R: int, *, cross: bool = False,
                 dense_first: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {"ln1": ParamSpec((R, d), ("layers", "embed"), "ones")}
    if kind == "M":
        spec["ssm"] = _ssm_specs(cfg, R)
    else:
        spec["attn"] = _attn_specs(cfg, R)
    if cross:
        spec["ln_x"] = ParamSpec((R, d), ("layers", "embed"), "ones")
        spec["cross"] = _attn_specs(cfg, R)
    if is_moe and cfg.moe is not None:
        spec["ln2"] = ParamSpec((R, d), ("layers", "embed"), "ones")
        spec["moe"] = _moe_specs(cfg, R)
    elif cfg.d_ff > 0 or dense_first:
        d_ff = cfg.dense_d_ff if (dense_first and cfg.dense_d_ff) else cfg.d_ff
        spec["ln2"] = ParamSpec((R, d), ("layers", "embed"), "ones")
        spec["mlp"] = _dense_mlp_specs(cfg, R, d_ff)
    return spec


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": {"w": ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal02")},
        "final_norm": {"w": ParamSpec((d,), ("embed",), "ones")},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": ParamSpec((d, cfg.vocab), ("embed", "vocab"))}

    groups = []
    cross = cfg.family == "encdec"
    for gi, g in enumerate(cfg.layer_groups):
        layers = []
        for pos, kind in enumerate(g.pattern):
            is_moe = bool(g.moe_mask and g.moe_mask[pos % len(g.moe_mask)] == "1")
            dense_first = (gi == 0 and pos == 0 and cfg.dense_d_ff > 0 and not is_moe)
            layers.append(_layer_specs(cfg, kind, is_moe, g.repeats, cross=cross,
                                       dense_first=dense_first))
        groups.append({"layers": layers})
    specs["groups"] = groups

    if cfg.encoder is not None:
        enc_layers = [_layer_specs(cfg, "A", False, cfg.encoder.n_layers)]
        specs["encoder"] = {
            "groups": [{"layers": enc_layers}],
            "final_norm": {"w": ParamSpec((d,), ("embed",), "ones")},
        }
    if cfg.vision is not None:
        specs["vision_proj"] = {
            "w": ParamSpec((cfg.vision.d_patch, d), (None, "embed")),
            "b": ParamSpec((d,), ("embed",), "zeros"),
        }
    return specs


# --------------------------------------------------------------------------
def _init_leaf(spec: ParamSpec, key, dtype):
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "a_log":
        # mamba2: A ~ uniform[1, 16], stored as log
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":
        # inverse softplus of dt ~ uniform[1e-3, 1e-1]
        dt = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if spec.init == "normal02":
        return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    # lecun: fan_in = product of all non-output dims after the stacking dim.
    # For (R, in, out...) matrices we take dim 1 (or dim 0 for 2D).
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    if len(shape) == 4:            # (R, in, h, hd) or (R, E, in, out)
        fan_in = shape[1] if spec.logical[1] == "embed" else shape[2]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_params(cfg: ModelConfig, rng, dtype=jnp.float32):
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    specs = param_specs(cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    specs = param_specs(cfg)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0
    for s in leaves:
        n = int(np.prod(s.shape))
        if active_only and s.tag == "routed_expert":
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total
