"""Training data pipeline: synthetic corpus -> packed token batches.

A deterministic Zipf-distributed synthetic corpus with injected n-gram
structure (so tiny models can actually reduce loss), packed into fixed
(batch, seq) arrays with next-token labels. Deterministic per (seed, step):
restart-safe — resuming from a checkpoint at step k reproduces batch k+1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram_period: int = 8      # injected structure: periodic bigrams


def synthesize_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    z = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len + 1))
    toks = (z % (cfg.vocab - 2)) + 1
    # inject learnable structure: every `period` steps, token = f(prev token)
    period = cfg.ngram_period
    idx = np.arange(1, cfg.seq_len + 1)
    mask = (idx % period) == 0
    toks[:, idx[mask]] = (toks[:, idx[mask] - 1] * 7 + 13) % (cfg.vocab - 2) + 1
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthesize_batch(cfg, step)
        step += 1
