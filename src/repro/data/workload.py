"""Synthetic serving workload matched to OpenOrca's published length
statistics (the dataset itself is not redistributable offline — DESIGN.md §9).

Prompt lengths ~ LogNormal fitted so median ≈ 150 tokens, long tail to ~2k
(system prompt + question); output lengths capped at the paper's
max-generation 512. All lengths are scaled down proportionally for the
tiny-model CPU benches via ``scale``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    n_requests: int
    vocab: int
    prompt_median: int = 150
    prompt_sigma: float = 0.8
    max_prompt: int = 2048
    min_prompt: int = 4
    max_new_tokens: int = 512
    scale: float = 1.0              # shrink for tiny-model CPU benches
    seed: int = 0
    # shared-system-prompt traffic: every request's prompt begins with one of
    # ``n_shared_prefixes`` fixed prefixes of exactly ``shared_prefix_len``
    # tokens (NOT scaled — callers size it in pages for the prefix-cache
    # benches). 0 disables.
    shared_prefix_len: int = 0
    n_shared_prefixes: int = 1
    # repetition-friendly (RAG-style extractive) traffic for the speculative-
    # decoding benches: a fraction of prompts are [passage, query, passage]
    # (the grounding span appears twice, as in retrieval-augmented serving)
    # and a fraction are periodic boilerplate (a short motif tiled to the
    # prompt length, as in templated/form traffic). Both give prompt-lookup
    # drafting earlier n-gram occurrences to match. 0 disables (default).
    extractive_frac: float = 0.0
    boilerplate_frac: float = 0.0
    boilerplate_period: int = 4
    # open-loop arrival schedule (bench_traffic): Poisson arrivals at
    # ``arrival_rate`` req/s, with a periodic burst phase — for
    # ``burst_duty`` of every ``burst_period_s`` the rate is multiplied by
    # ``burst_mult`` (bursty production traffic; §5's constant-concurrency
    # driver is the closed-loop special case). 0 disables (closed loop).
    arrival_rate: float = 0.0
    burst_mult: float = 1.0
    burst_period_s: float = 10.0
    burst_duty: float = 0.3


def sample_workload(spec: WorkloadSpec) -> Tuple[List[np.ndarray], List[int]]:
    """Returns (prompts, max_new_tokens per request)."""
    rng = np.random.default_rng(spec.seed)
    mu = np.log(spec.prompt_median)
    lens = np.exp(rng.normal(mu, spec.prompt_sigma, spec.n_requests))
    lens = np.clip(lens * spec.scale, max(int(spec.min_prompt * spec.scale), 2),
                   max(int(spec.max_prompt * spec.scale), 4)).astype(int)
    outs = np.minimum(
        rng.geometric(1.0 / max(spec.max_new_tokens * spec.scale / 2, 2), spec.n_requests),
        max(int(spec.max_new_tokens * spec.scale), 4),
    ).astype(int)
    outs = np.maximum(outs, 2)
    prompts = [rng.integers(1, spec.vocab, n).astype(np.int32) for n in lens]
    shapes = rng.random(spec.n_requests)
    for i, n in enumerate(lens):
        if shapes[i] < spec.extractive_frac and n >= 8:
            # passage + query + passage: the passage span repeats verbatim
            q = max(n // 8, 2)
            passage = rng.integers(1, spec.vocab, (n - q + 1) // 2).astype(np.int32)
            query = rng.integers(1, spec.vocab, q).astype(np.int32)
            prompts[i] = np.concatenate([passage, query, passage])[:n]
        elif shapes[i] < spec.extractive_frac + spec.boilerplate_frac and n >= 4:
            per = max(min(spec.boilerplate_period, n // 2), 1)
            motif = rng.integers(1, spec.vocab, per).astype(np.int32)
            prompts[i] = np.tile(motif, -(-n // per))[:n]
    if spec.shared_prefix_len > 0:
        prefixes = [rng.integers(1, spec.vocab, spec.shared_prefix_len).astype(np.int32)
                    for _ in range(max(spec.n_shared_prefixes, 1))]
        prompts = [np.concatenate([prefixes[i % len(prefixes)], p])
                   for i, p in enumerate(prompts)]
    return prompts, outs.tolist()


def sample_arrivals(spec: WorkloadSpec) -> List[float]:
    """Arrival offsets (seconds from bench start, sorted) for the open-loop
    schedule: a piecewise-constant-rate Poisson process — base rate
    ``arrival_rate``, stepped up to ``burst_mult`` x for the first
    ``burst_duty`` fraction of every ``burst_period_s`` window. Seeded from
    ``spec.seed`` but decoupled from prompt sampling (a different stream), so
    changing the schedule never reshuffles the prompts."""
    if spec.arrival_rate <= 0:
        return [0.0] * spec.n_requests
    rng = np.random.default_rng((spec.seed, 0xA221))

    def rate_at(t: float) -> float:
        if spec.burst_mult <= 1.0 or spec.burst_period_s <= 0:
            return spec.arrival_rate
        phase = (t % spec.burst_period_s) / spec.burst_period_s
        return spec.arrival_rate * (spec.burst_mult if phase < spec.burst_duty
                                    else 1.0)

    # thinning: draw at the peak rate, accept with prob rate(t)/peak
    peak = spec.arrival_rate * max(spec.burst_mult, 1.0)
    t = 0.0
    out: List[float] = []
    while len(out) < spec.n_requests:
        t += float(rng.exponential(1.0 / peak))
        if rng.random() < rate_at(t) / peak:
            out.append(t)
    return out
