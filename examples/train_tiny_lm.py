"""Train a small LM for a few hundred steps with the full training substrate:
AdamW + cosine schedule, remat, deterministic data pipeline, async
checkpointing, and automatic restart (kill it mid-run and re-launch — it
resumes from the latest checkpoint and reproduces the uninterrupted loss).

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""
import argparse

from repro.configs import tiny_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.training.train_step import TrainConfig
from repro.training.trainer import TrainerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--grad-compression", action="store_true",
                    help="int8 gradient all-reduce with error feedback")
    args = ap.parse_args()

    cfg = tiny_config(args.arch)
    model = build_model(cfg)
    print(f"training {cfg.name}: {cfg.param_count():,} params, "
          f"ckpt -> {args.ckpt_dir}")
    out = train(
        model,
        DataConfig(vocab=cfg.vocab, batch=8, seq_len=64),
        TrainConfig(peak_lr=1e-3, warmup=20, total_steps=args.steps,
                    grad_compression=args.grad_compression),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      log_every=20),
    )
    losses = out["losses"]
    if out["start"] > 0:
        print(f"(resumed from checkpoint at step {out['start']})")
    for i in range(0, len(losses), max(len(losses) // 10, 1)):
        print(f"step {out['start']+i:>4d}  loss {losses[i]:.4f}")
    print(f"final loss {losses[-1]:.4f}  (started at {losses[0]:.4f})")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
