"""Quickstart: load an architecture config, build the model, and generate
tokens through the continuous-batching engine (greedy, CPU, reduced config).

    PYTHONPATH=src python examples/quickstart.py --arch qwen2.5-3b
"""
import argparse

import jax
import numpy as np

from repro.configs import ALL_ARCHS, tiny_config
from repro.core import EngineConfig, InferenceEngine, Request, now, request_metrics
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ALL_ARCHS)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = tiny_config(args.arch)
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}  "
          f"params={cfg.param_count():,} (reduced; full config: "
          f"{tiny_config.__module__ and __import__('repro.configs', fromlist=['get_config']).get_config(args.arch).param_count()/1e9:.1f}B)")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=128, max_seq=128,
        prefill_bucket=16, greedy=True))

    rng = np.random.default_rng(0)
    reqs = [Request(req_id=f"demo-{i}",
                    prompt_tokens=rng.integers(1, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=args.max_new) for i in range(3)]
    t0 = now()
    engine.generate(reqs)
    dt = now() - t0
    for r in reqs:
        m = request_metrics(r)
        print(f"{r.req_id}: {r.generated[:10]}...  "
              f"ttft={m.ttft*1e3:.0f}ms tbt={m.tbt*1e3:.1f}ms/token")
    total = sum(r.n_generated for r in reqs)
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.0f} tok/s, includes jit compile)")


if __name__ == "__main__":
    main()
