"""End-to-end serving driver (the paper's system in one script): gateway with
auth + rate limiting + content filtering, replica router, two continuous-
batching replicas, concurrent streaming clients — then the §5.1 latency
decomposition, comparing the baseline (FastAPI-style) and ScaleLLM gateways.

    PYTHONPATH=src python examples/serve_endpoint.py
"""
import asyncio

import jax

from repro.configs import tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, MetricsSink,
                        Replica, ReplicaRouter, RouterConfig,
                        baseline_gateway_config, scale_gateway_config, summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.core.safety import Authenticator, ContentFilter, TokenBucket
from repro.data.workload import WorkloadSpec, sample_workload
from repro.models import build_model

ARCH = "mixtral-8x7b"       # the paper's model (reduced config on CPU)


async def serve_once(gateway_cfg, model, params, cfg, concurrency=6, n_requests=18):
    replicas = [Replica(f"rep{i}", InferenceEngine(model, params, EngineConfig(
        max_slots=4, page_size=8, num_pages=256, max_seq=160, prefill_bucket=16,
    ))).start() for i in range(2)]
    sink = MetricsSink()
    router = ReplicaRouter(replicas, RouterConfig(policy="least_loaded"), sink=sink)
    auth = Authenticator()
    gw = Gateway(router, gateway_cfg, auth=auth,
                 rate_limiter=TokenBucket(rate=500, burst=1000),
                 content_filter=ContentFilter(blocked=set()),
                 require_auth=True)
    prompts, _ = sample_workload(WorkloadSpec(n_requests=n_requests, vocab=cfg.vocab,
                                              scale=0.05, seed=1))
    res = await run_workload(gw, prompts, concurrency=concurrency,
                             max_new_tokens=12, auth_token=auth.issue("demo-user"))
    merge_engine_timestamps(res.requests, gw)
    for r in replicas:
        r.stop()
    return summarize(res.requests, res.t_start, res.t_end, concurrency)


def main():
    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"serving {ARCH} (reduced) on 2 replicas, temp=0.5 top_p=0.7\n")
    print(f"{'gateway':<10} {'thpt tok/s':>10} {'TTFT ms':>9} {'TBT ms':>8} "
          f"{'gw-lat ms':>10} {'engine ms':>10}")
    for name, gw_cfg in (("baseline", baseline_gateway_config()),
                         ("scale", scale_gateway_config())):
        s = asyncio.run(serve_once(gw_cfg, model, params, cfg))
        print(f"{name:<10} {s.throughput_tok_s:>10.0f} {s.mean['ttft_user']*1e3:>9.1f} "
              f"{s.mean['tbt']*1e3:>8.2f} {s.mean['gateway_latency']*1e3:>10.1f} "
              f"{s.mean['engine_latency']*1e3:>10.1f}")


if __name__ == "__main__":
    main()
