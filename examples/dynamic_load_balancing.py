"""Paper §6 blueprint: a dynamic inference load-balancing system over
HETEROGENEOUS replica classes.

Fleet: one "high_tp" replica (big batch slots — stands in for the 1xTP8
deployment) + three "high_replica" replicas (small slots — the 4xTP2
deployment). The dynamic policy routes by live concurrency: below the
threshold it prefers the high-TP class, above it the replica pool — and the
sweep shows each class winning in its regime, with the dynamic router
tracking the better of the two everywhere.

    PYTHONPATH=src python examples/dynamic_load_balancing.py
"""
import asyncio

import jax

from repro.configs import tiny_config
from repro.core import (EngineConfig, Gateway, InferenceEngine, Replica,
                        ReplicaRouter, RouterConfig, scale_gateway_config,
                        summarize)
from repro.core.client import merge_engine_timestamps, run_workload
from repro.data.workload import WorkloadSpec, sample_workload
from repro.models import build_model

ARCH = "mixtral-8x7b"
THRESHOLD = 8


def build_fleet(model, params, classes):
    fleet = []
    for i, (klass, slots) in enumerate(classes):
        eng = InferenceEngine(model, params, EngineConfig(
            max_slots=slots, page_size=8, num_pages=256, max_seq=160,
            prefill_bucket=16, greedy=True))
        fleet.append(Replica(f"{klass}-{i}", eng, klass=klass).start())
    return fleet


async def measure(policy, classes, model, params, cfg, concurrency):
    fleet = build_fleet(model, params, classes)
    router = ReplicaRouter(fleet, RouterConfig(policy=policy,
                                               dynamic_threshold=THRESHOLD))
    gw = Gateway(router, scale_gateway_config())
    prompts, _ = sample_workload(WorkloadSpec(n_requests=2 * concurrency,
                                              vocab=cfg.vocab, scale=0.04, seed=3))
    res = await run_workload(gw, prompts, concurrency=concurrency, max_new_tokens=8)
    merge_engine_timestamps(res.requests, gw)
    s = summarize(res.requests, res.t_start, res.t_end, concurrency)
    dist = {}
    for r in res.requests:
        dist[r.replica_id] = dist.get(r.replica_id, 0) + 1
    for r in fleet:
        r.stop()
    return s, dist


def main():
    cfg = tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    hetero = [("high_tp", 8), ("high_replica", 2), ("high_replica", 2),
              ("high_replica", 2)]
    print(f"blueprint fleet: 1x high_tp(8 slots) + 3x high_replica(2 slots), "
          f"threshold={THRESHOLD}\n")
    print(f"{'concurrency':>11} {'policy':<12} {'thpt tok/s':>10}  routed-to")
    for c in (2, 16):
        for policy in ("dynamic", "least_loaded"):
            s, dist = asyncio.run(measure(policy, hetero, model, params, cfg, c))
            klass_counts = {}
            for rid, n in dist.items():
                klass_counts[rid.rsplit("-", 1)[0]] = \
                    klass_counts.get(rid.rsplit("-", 1)[0], 0) + n
            print(f"{c:>11} {policy:<12} {s.throughput_tok_s:>10.0f}  {klass_counts}")
    print("\ndynamic policy routes low concurrency to the high-TP class and "
          "high concurrency to the replica pool (paper §6).")


if __name__ == "__main__":
    main()
